"""CLI: ``python -m tools.lint [--json] [--select RULE ...] PATH ...``"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from . import lint_paths, make_rules, render_human, render_json


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="tools.lint",
        description="repro-lint: JAX/Pallas-aware static analysis for this "
                    "repo (exit 0 clean, 1 findings, 2 usage error)")
    parser.add_argument("paths", nargs="*", help="files or package dirs")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable output")
    parser.add_argument("--select", action="append", metavar="RULE",
                        help="run only these rules (by name or GLnnn code); "
                             "repeatable")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in make_rules():
            print(f"{rule.code}  {rule.name:28s} {rule.description}")
        return 0
    if not args.paths:
        parser.print_usage(sys.stderr)
        print("tools.lint: error: no paths given", file=sys.stderr)
        return 2
    if args.select and not make_rules(args.select):
        print(f"tools.lint: error: no rule matches {args.select}",
              file=sys.stderr)
        return 2

    findings = lint_paths(args.paths, select=args.select)
    print(render_json(findings) if args.as_json else render_human(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
