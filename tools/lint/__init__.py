"""repro-lint: the repo's JAX/Pallas-aware static-analysis pass.

Usage:  ``python -m tools.lint src/ benchmarks/``  (exit 0 clean,
1 findings, 2 usage error).  Library entry points: `lint_source`,
`lint_paths`.  The runtime complement lives in
`tools.lint.recompile_guard` (XLA recompile counting) and is imported
separately because it needs jax; this package does not.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .core import (FileContext, Finding, Linter, Rule, render_human,
                   render_json, walk_py)
from .rules import ALL_RULES, make_rules

__all__ = ["FileContext", "Finding", "Linter", "Rule", "ALL_RULES",
           "make_rules", "lint_source", "lint_paths", "render_human",
           "render_json", "walk_py"]


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string (the test-suite entry point).  `path`
    matters: some rules are path-scoped (GL107 is strict only in
    serve/checkpoint paths)."""
    return Linter(make_rules(select)).lint_source(source, path)


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    return Linter(make_rules(select)).lint_paths(paths)
