"""Runtime complement to repro-lint: count XLA compilations, pin hot paths.

The static rules catch retrace *patterns* (GL109); this guard catches the
retrace *events* the patterns cause.  It hooks ``jax.monitoring``'s
duration events — ``/jax/core/compile/backend_compile_duration`` fires
exactly once per backend compilation, and cache-hit dispatches fire
nothing — so a warm hot path wrapped in `assert_no_recompiles()` proves
the pow2-bucketing/cache-key contract holds: in-bucket shape variation
must not grow the jit cache.

jax.monitoring has no per-listener unregister, so one module-level
listener is installed lazily on first use and never removed; context
managers snapshot the monotonic counter around their block.

Usage::

    from tools.lint.recompile_guard import assert_no_recompiles, track_compiles

    with track_compiles() as rec:      # observe
        f(x)
    print(rec.count)

    with assert_no_recompiles():       # enforce (raises RecompileError)
        f(y)                           # y in the same bucket as the warmup

    def test_hot_path(no_recompile):   # pytest fixture (tests/conftest.py)
        warmup()
        with no_recompile():
            serve()
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_counts = {"compiles": 0}
_installed = False


class RecompileError(AssertionError):
    """A guarded block triggered more XLA compilations than allowed."""


def _listener(event: str, duration: float, **kwargs) -> None:
    if event == _COMPILE_EVENT:
        _counts["compiles"] += 1


def _ensure_listener() -> None:
    global _installed
    if _installed:
        return
    import jax.monitoring
    jax.monitoring.register_event_duration_secs_listener(_listener)
    _installed = True


def compile_count() -> int:
    """Monotonic count of backend compilations observed since the
    listener was installed (this process, all devices)."""
    _ensure_listener()
    return _counts["compiles"]


@dataclasses.dataclass
class CompileRecord:
    """Filled in when the tracking block exits."""
    count: int = 0


@contextlib.contextmanager
def track_compiles() -> Iterator[CompileRecord]:
    """Observe how many XLA compilations the block triggers."""
    _ensure_listener()
    rec = CompileRecord()
    start = _counts["compiles"]
    try:
        yield rec
    finally:
        rec.count = _counts["compiles"] - start


@contextlib.contextmanager
def assert_no_recompiles(allowed: int = 0,
                         label: str = "") -> Iterator[CompileRecord]:
    """Fail with `RecompileError` when the block compiles more than
    `allowed` times.  Wrap *warm* paths only — warm the cache first."""
    with track_compiles() as rec:
        yield rec
    if rec.count > allowed:
        where = f" in {label}" if label else ""
        raise RecompileError(
            f"{rec.count} XLA compilation(s){where} where at most "
            f"{allowed} allowed: a hot path is retracing (cache key or "
            f"pow2 bucketing broke; see tools/lint GL109 and "
            f"core/explorer.py pow2_bucket)")
