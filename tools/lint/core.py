"""repro-lint core: the rule protocol, file walker, suppressions, output.

The linter is a plain-AST analysis (no imports of the linted code, no jax
dependency) so it runs anywhere Python runs and can never be broken by the
code it checks.  Each `Rule` is a small visitor over one parsed file; the
`Linter` walks files/packages, runs every enabled rule, applies
suppressions, and renders findings as human lines or JSON.

Suppressions (all take a comma-separated rule-name list, or ``all``):

- ``# lint: disable=RULE`` on the flagged line — or on a comment-only line
  directly above it — suppresses that line's findings;
- the same comment on a ``def``/``class`` line suppresses the rule for the
  entire function/class body (use for a documented invariant the rule
  cannot see, e.g. "only ever called under the caller's lock");
- ``# lint: disable-file=RULE`` anywhere in a file suppresses the rule for
  the whole file.

Exit-code contract (``python -m tools.lint``): 0 = clean, 1 = findings,
2 = usage/internal error.  A file that fails to parse is itself a finding
(``GL000 parse-error``), not a crash.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

_SUPPRESS_LINE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_\-, ]+)")
_SUPPRESS_FILE = re.compile(r"#\s*lint:\s*disable-file=([A-Za-z0-9_\-, ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str        # rule name, e.g. "prng-key-reuse"
    code: str        # stable id, e.g. "GL101"
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.code} [{self.rule}] {self.message}")

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @property
    def sort_key(self):
        return (self.path, self.line, self.col, self.code)


class Rule:
    """Base class for one lint rule.

    Subclasses set ``name`` (kebab-case, what suppressions reference),
    ``code`` (stable GLnnn id), ``description`` (one line, shown by
    ``--list-rules``), and implement ``check(ctx) -> Iterator[Finding]``.
    """

    name: str = "abstract-rule"
    code: str = "GL000"
    description: str = ""

    def check(self, ctx: "FileContext") -> Iterator[Finding]:
        raise NotImplementedError
        yield  # pragma: no cover

    def finding(self, ctx: "FileContext", node: ast.AST,
                message: str) -> Finding:
        return Finding(self.name, self.code, ctx.path,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


class FileContext:
    """One parsed file plus the shared per-file analyses rules lean on:
    import-alias resolution, AST parent links, and the suppression map."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.aliases = _import_aliases(self.tree)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self._line_suppress: Dict[int, Set[str]] = {}
        self._file_suppress: Set[str] = set()
        self._scan_suppressions()
        # def/class-line suppressions extend over the whole body
        self._span_suppress: List = []   # (first, last, names)
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                names = self._line_suppress.get(node.lineno)
                if names:
                    last = max((n.lineno for n in ast.walk(node)
                                if hasattr(n, "lineno")), default=node.lineno)
                    self._span_suppress.append((node.lineno, last, names))

    def _scan_suppressions(self) -> None:
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_FILE.search(text)
            if m:
                self._file_suppress |= _split_names(m.group(1))
                continue
            m = _SUPPRESS_LINE.search(text)
            if not m:
                continue
            names = _split_names(m.group(1))
            self._line_suppress.setdefault(i, set()).update(names)
            # a comment-only line covers the next source line
            if text.lstrip().startswith("#"):
                self._line_suppress.setdefault(i + 1, set()).update(names)

    def suppressed(self, rule: str, line: int) -> bool:
        if {"all", rule} & self._file_suppress:
            return True
        names = self._line_suppress.get(line, ())
        if "all" in names or rule in names:
            return True
        for first, last, span_names in self._span_suppress:
            if first <= line <= last and {"all", rule} & span_names:
                return True
        return False

    # ---- shared helpers ----------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted name of a Name/Attribute chain with import aliases
        resolved: with ``import jax.numpy as jnp``, ``jnp.dot`` ->
        ``jax.numpy.dot``.  None for non-name expressions."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        root = self.aliases.get(parts[0], parts[0])
        return ".".join([root] + parts[1:])

    def call_name(self, call: ast.Call) -> Optional[str]:
        return self.resolve(call.func)

    def functions(self) -> Iterator[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    def line_has_marker(self, line: int, marker: str) -> bool:
        """True when `marker` appears in a comment on `line` or on the
        comment-only line directly above it."""
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines) and marker in self.lines[ln - 1]:
                return True
        return False


def _split_names(raw: str) -> Set[str]:
    return {p.strip() for p in raw.split(",") if p.strip()}


def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    """local name -> dotted module it stands for."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


class Linter:
    """Run a rule set over files/trees and collect findings."""

    def __init__(self, rules: Sequence[Rule]):
        self.rules = list(rules)

    def lint_source(self, source: str, path: str = "<string>") -> List[Finding]:
        try:
            ctx = FileContext(path, source)
        except SyntaxError as e:
            return [Finding("parse-error", "GL000", path, e.lineno or 1,
                            e.offset or 0, f"file does not parse: {e.msg}")]
        out: List[Finding] = []
        for rule in self.rules:
            for f in rule.check(ctx):
                if not ctx.suppressed(f.rule, f.line):
                    out.append(f)
        return sorted(out, key=lambda f: f.sort_key)

    def lint_file(self, path: str) -> List[Finding]:
        try:
            with open(path, encoding="utf-8") as fh:
                source = fh.read()
        except OSError as e:
            return [Finding("parse-error", "GL000", path, 1, 0,
                            f"unreadable file: {e}")]
        return self.lint_source(source, path)

    def lint_paths(self, paths: Iterable[str]) -> List[Finding]:
        out: List[Finding] = []
        for p in paths:
            for f in sorted(walk_py(p)):
                out.extend(self.lint_file(f))
        return sorted(out, key=lambda f: f.sort_key)


def walk_py(path: str) -> Iterator[str]:
    """Yield .py files under `path` (a file or a package/directory),
    skipping hidden and cache directories."""
    if os.path.isfile(path):
        if path.endswith(".py"):
            yield path
        return
    for root, dirs, files in os.walk(path):
        dirs[:] = [d for d in dirs
                   if not d.startswith(".") and d != "__pycache__"]
        for name in sorted(files):
            if name.endswith(".py"):
                yield os.path.join(root, name)


def render_human(findings: Sequence[Finding]) -> str:
    lines = [f.format() for f in findings]
    n = len(findings)
    lines.append(f"{n} finding{'s' if n != 1 else ''}")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps({"findings": [f.as_dict() for f in findings],
                       "count": len(findings)}, indent=2)
