"""GL106 lock-discipline: within a class that owns a ``threading.Lock`` /
``RLock`` / ``Condition`` attribute, any ``self.<attr>`` that is *mutated*
under ``with self._lock`` in some method is lock-guarded state — touching
it lock-free in another method is a data race (the invariant PR 7's
split-dispatch API exists to keep).  A ``Condition(self._lock)`` shares
the lock, so ``with self._space:`` also counts as holding it.

``__init__`` is exempt (no concurrent access before construction
completes).  Methods that are only ever called with the lock already held
document that contract with a def-line ``# lint: disable=lock-discipline``.

GL111 swap-lock-bypass: the hot-swap race bug class.  ``DSEServer.swap``
mutates engine and cache state, so on a server wrapped by a live
``ServeFrontend`` it must run under the front-end lock — that is what the
locked ``ServeFrontend.swap`` method is for.  A direct
``<anything>.server.swap(...)`` call reaches around the wrapper and races
the former/dispatcher threads; the rule flags the pattern anywhere it is
not under a held ``with self.<lock>:`` block.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..core import FileContext, Finding, Rule

_LOCK_CTORS = {"threading.Lock", "threading.RLock", "threading.Condition"}
#: self.<attr>.<method>() calls that mutate the attribute in place
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popleft",
             "popitem", "clear", "update", "setdefault", "add", "discard",
             "appendleft", "move_to_end", "put"}


class LockDiscipline(Rule):
    name = "lock-discipline"
    code = "GL106"
    description = ("attribute mutated under self._lock in one method but "
                   "touched lock-free in another")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(ctx, node)

    def _check_class(self, ctx: FileContext, cls: ast.ClassDef
                     ) -> Iterator[Finding]:
        locks = self._lock_attrs(ctx, cls)
        if not locks:
            return
        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

        guarded: Set[str] = set()
        for m in methods:
            if m.name == "__init__":
                continue
            for node, held in self._walk_with_lock(m, locks):
                if held:
                    attr = self._self_attr_mutation(node)
                    if attr and attr not in locks:
                        guarded.add(attr)
        if not guarded:
            return

        for m in methods:
            if m.name == "__init__":
                continue
            reported: Set[Tuple[str, int]] = set()
            for node, held in self._walk_with_lock(m, locks):
                if held or not isinstance(node, ast.Attribute):
                    continue
                if isinstance(node.value, ast.Name) and \
                        node.value.id == "self" and node.attr in guarded:
                    key = (node.attr, node.lineno)
                    if key in reported:
                        continue
                    reported.add(key)
                    yield self.finding(
                        ctx, node,
                        f"'self.{node.attr}' is mutated under the lock "
                        f"elsewhere in {cls.name} but touched here without "
                        f"holding it; wrap in `with self.{min(locks)}:` (or "
                        f"suppress on the def line if the caller holds it)")

    def _lock_attrs(self, ctx: FileContext, cls: ast.ClassDef) -> Set[str]:
        locks: Set[str] = set()
        # two passes so Condition(self._lock) resolves regardless of order
        for _ in range(2):
            for node in ast.walk(cls):
                if not (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)):
                    continue
                name = ctx.call_name(node.value)
                if name not in _LOCK_CTORS and name not in (
                        "Lock", "RLock", "Condition"):
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        locks.add(t.attr)
        return locks

    def _walk_with_lock(self, fn, locks: Set[str]
                        ) -> Iterator[Tuple[ast.AST, bool]]:
        """Yield (node, lock_held) over fn's body, excluding nested scopes."""

        def visit(node: ast.AST, held: bool) -> Iterator[Tuple[ast.AST, bool]]:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda, ast.ClassDef)):
                    continue
                child_held = held
                if isinstance(child, ast.With):
                    for item in child.items:
                        e = item.context_expr
                        if isinstance(e, ast.Attribute) and \
                                isinstance(e.value, ast.Name) and \
                                e.value.id == "self" and e.attr in locks:
                            child_held = True
                yield child, child_held
                yield from visit(child, child_held)

        yield from visit(fn, False)

    def _self_attr_mutation(self, node: ast.AST) -> Optional[str]:
        """Name of the self attribute this node mutates, if any."""
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, (ast.Store, ast.Del)) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            return node.attr
        if isinstance(node, ast.Subscript):
            v = node.value
            if isinstance(node.ctx, (ast.Store, ast.Del)) and \
                    isinstance(v, ast.Attribute) and \
                    isinstance(v.value, ast.Name) and v.value.id == "self":
                return v.attr
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            v = node.func.value
            if isinstance(v, ast.Attribute) and \
                    isinstance(v.value, ast.Name) and v.value.id == "self":
                return v.attr
        return None


class SwapLockBypass(Rule):
    name = "swap-lock-bypass"
    code = "GL111"
    description = ("direct .server.swap() call bypasses the front-end "
                   "lock; use the locked ServeFrontend.swap")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        walker = LockDiscipline()
        visited: Set[int] = set()
        # inside classes: a held `with self.<lock>:` legitimizes the call
        # (ServeFrontend.swap itself is exactly that)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            locks = walker._lock_attrs(ctx, node)
            for m in node.body:
                if not isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for sub, held in walker._walk_with_lock(m, locks):
                    visited.add(id(sub))
                    if not held and self._is_server_swap(sub):
                        yield self._flag(ctx, sub)
        # everywhere else (module level, free functions, nested scopes):
        # there is no front-end lock to hold, so the pattern is always a
        # bypass
        for node in ast.walk(ctx.tree):
            if id(node) not in visited and self._is_server_swap(node):
                yield self._flag(ctx, node)

    def _is_server_swap(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "swap"
                and isinstance(node.func.value, ast.Attribute)
                and node.func.value.attr == "server")

    def _flag(self, ctx: FileContext, node: ast.AST) -> Finding:
        return self.finding(
            ctx, node,
            "direct `.server.swap(...)` on a frontend-wrapped server "
            "races the former/dispatcher threads (engine + cache state "
            "mutate outside the front-end lock); call the locked "
            "`ServeFrontend.swap(...)` instead")
