"""GL105 donated-after-use: a buffer passed at a ``donate_argnums``
position is deleted by XLA the moment the donating call runs — any later
read of that Python name sees a dead array and raises (or, under some
backends, silently aliases garbage).  The safe idiom is same-statement
rebinding: ``carry, metrics = epoch(carry, ...)`` (core/train.py).
Flags reads of a donated Name after the donating call and before the name
is rebound.  Only constant ``donate_argnums`` are analyzed.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..core import FileContext, Finding, Rule


def _const_argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            return tuple(e.value for e in v.elts)
        return None     # dynamic donate_argnums: skip
    return None


class DonatedAfterUse(Rule):
    name = "donated-after-use"
    code = "GL105"
    description = ("buffer read after being donated to a jit call "
                   "(donate_argnums) without rebinding")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        donors = self._donating_callables(ctx)
        if not donors:
            return
        for fn in ctx.functions():
            yield from self._check_scope(ctx, fn, donors)

    def _donating_callables(self, ctx: FileContext) -> Dict[str, Tuple[int, ...]]:
        """name -> donated positions, for `f = jax.jit(g, donate_argnums=...)`
        bindings and defs decorated with partial(jax.jit, donate_argnums=...)."""
        out: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    ctx.call_name(node.value) in ("jax.jit", "jax.pmap"):
                nums = _const_argnums(node.value)
                if nums:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = nums
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and \
                            ctx.call_name(dec) in ("functools.partial",
                                                   "partial") and \
                            dec.args and \
                            ctx.resolve(dec.args[0]) in ("jax.jit",
                                                         "jax.pmap"):
                        nums = _const_argnums(dec)
                        if nums:
                            out[node.name] = nums
        return out

    def _check_scope(self, ctx: FileContext, fn,
                     donors: Dict[str, Tuple[int, ...]]) -> Iterator[Finding]:
        # (lineno, col, kind, name, node); kinds: donate < bind < read on ties
        events: List[Tuple[int, int, int, str, ast.AST]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in donors:
                stmt_targets = self._enclosing_targets(ctx, node)
                for pos in donors[node.func.id]:
                    if pos < len(node.args) and \
                            isinstance(node.args[pos], ast.Name):
                        donated = node.args[pos].id
                        if donated in stmt_targets:
                            continue    # same-statement rebind: safe
                        # anchor at the call's last line so the call's own
                        # argument Names never read as use-after-donate
                        end = getattr(node, "end_lineno", None) or node.lineno
                        events.append((end, node.col_offset, 0,
                                       donated, node))
            elif isinstance(node, ast.Name):
                kind = 1 if isinstance(node.ctx, ast.Store) else 2
                events.append((node.lineno, node.col_offset, kind,
                               node.id, node))
        events.sort(key=lambda e: (e[0], e[2]))

        dead: Dict[str, int] = {}     # name -> donation lineno
        for lineno, _col, kind, name, node in events:
            if kind == 0:
                dead[name] = lineno
            elif kind == 1:
                dead.pop(name, None)
            elif name in dead and lineno > dead[name]:
                yield self.finding(
                    ctx, node,
                    f"'{name}' was donated at line {dead[name]} and its "
                    f"buffer is gone; rebind it from the call's result "
                    f"(`x, ... = f(x, ...)`) before reading it again")
                dead.pop(name)      # one finding per donation

    def _enclosing_targets(self, ctx: FileContext, call: ast.Call) -> Set[str]:
        node: ast.AST = call
        while node in ctx.parents:
            node = ctx.parents[node]
            if isinstance(node, ast.Assign):
                out: Set[str] = set()
                for t in node.targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name):
                            out.add(sub.id)
                return out
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
        return set()
