"""The repro-lint rule catalog.  Each rule encodes one bug class a past
PR fixed by hand (see CONTRIBUTING.md for the provenance table)."""
from __future__ import annotations

from typing import List, Optional, Sequence

from ..core import Rule
from .prng import PrngKeyReuse, SeedInt32Overflow
from .jit_purity import HostSyncInJit, JitPerCall
from .sharding_axes import PSpecUnknownAxis
from .donation import DonatedAfterUse
from .locks import LockDiscipline, SwapLockBypass
from .excepts import OverbroadExcept
from .pallas_blocks import PallasBlockSpec
from .nan_guard import NanTransparentViolation
from .dispatch_sync import HostSyncInDispatch

ALL_RULES = [
    PrngKeyReuse,              # GL101
    SeedInt32Overflow,         # GL102
    HostSyncInJit,             # GL103
    PSpecUnknownAxis,          # GL104
    DonatedAfterUse,           # GL105
    LockDiscipline,            # GL106
    OverbroadExcept,           # GL107
    PallasBlockSpec,           # GL108
    JitPerCall,                # GL109
    NanTransparentViolation,   # GL110
    SwapLockBypass,            # GL111
    HostSyncInDispatch,        # GL112
]


def make_rules(select: Optional[Sequence[str]] = None) -> List[Rule]:
    """Instantiate the rule set; `select` filters by rule name or code."""
    rules = [cls() for cls in ALL_RULES]
    if select:
        wanted = set(select)
        rules = [r for r in rules if r.name in wanted or r.code in wanted]
    return rules
