"""PRNG hygiene rules.

GL101 prng-key-reuse: a PRNG key Name consumed by two ``jax.random.*``
draws without an intervening ``split``/``fold_in`` rebinding produces
correlated randomness — the draws are identical, not independent.  Also
flags a key bound outside a loop but consumed inside it (every iteration
sees the same stream).

GL102 seed-int32-overflow: host-side Python-int arithmetic fed straight
into ``PRNGKey`` can silently wrap int32 for large seeds/offsets (the
PR-3 bug).  The sanctioned forms are ``jax.random.fold_in(key, i)`` or
masking the int64 sum with ``& 0xFFFFFFFF`` before key construction
(`core/explorer.py` ``task_keys``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..core import FileContext, Finding, Rule

# jax.random callables that *derive* keys rather than consume entropy
_NON_CONSUMERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
                  "wrap_key_data", "clone", "key_impl"}


def _is_random_consumer(ctx: FileContext, call: ast.Call) -> bool:
    name = ctx.call_name(call)
    if not name or not name.startswith("jax.random."):
        return False
    return name.rsplit(".", 1)[1] not in _NON_CONSUMERS


def _key_arg(call: ast.Call) -> Optional[str]:
    """The bare-Name key argument of a jax.random consumer, if any."""
    args = [a for a in call.args]
    for kw in call.keywords:
        if kw.arg == "key":
            args.insert(0, kw.value)
    if args and isinstance(args[0], ast.Name):
        return args[0].id
    return None


def _bound_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


class PrngKeyReuse(Rule):
    name = "prng-key-reuse"
    code = "GL101"
    description = ("PRNG key passed to two jax.random draws (or consumed "
                   "inside a loop) without split/fold_in between")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.functions():
            yield from self._check_scope(ctx, fn)

    def _check_scope(self, ctx: FileContext, fn) -> Iterator[Finding]:
        # events in source order: ('bind'|'consume', name, node, loop_depth)
        events: List[Tuple[str, str, ast.AST, int]] = []

        def visit(node: ast.AST, depth: int) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda, ast.ClassDef)):
                    continue    # separate scope
                if isinstance(child, (ast.Assign, ast.AugAssign,
                                      ast.AnnAssign)):
                    for sub in ast.walk(child):
                        if isinstance(sub, ast.Call) and \
                                _is_random_consumer(ctx, sub):
                            key = _key_arg(sub)
                            if key:
                                events.append(("consume", key, sub, depth))
                    targets = (child.targets
                               if isinstance(child, ast.Assign)
                               else [child.target])
                    for t in targets:
                        for n in _bound_names(t):
                            events.append(("bind", n, child, depth))
                    continue
                if isinstance(child, ast.Call) and \
                        _is_random_consumer(ctx, child):
                    key = _key_arg(child)
                    if key:
                        events.append(("consume", key, child, depth))
                in_loop = isinstance(child, (ast.For, ast.While))
                if in_loop and isinstance(child, ast.For):
                    for n in _bound_names(child.target):
                        events.append(("bind", n, child, depth + 1))
                visit(child, depth + 1 if in_loop else depth)

        visit(fn, 0)

        last_consume: Dict[str, ast.AST] = {}
        bind_depth: Dict[str, int] = {a.arg: 0 for a in fn.args.args}
        for kind, name, node, depth in events:
            if kind == "bind":
                last_consume.pop(name, None)
                bind_depth[name] = depth
            else:
                if name in last_consume:
                    yield self.finding(
                        ctx, node,
                        f"key '{name}' already consumed at line "
                        f"{last_consume[name].lineno}; split/fold_in before "
                        f"drawing again")
                elif depth > bind_depth.get(name, 0):
                    yield self.finding(
                        ctx, node,
                        f"key '{name}' bound outside this loop but consumed "
                        f"inside it; fold_in the loop index for a fresh key "
                        f"per iteration")
                last_consume[name] = node


def _mentions_seedish(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and "seed" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "seed" in sub.attr.lower():
            return True
    return False


def _is_masked(node: ast.AST) -> bool:
    """True for `expr & 0xFFFFFFFF`-style sanctioned masking."""
    return isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitAnd)


class SeedInt32Overflow(Rule):
    name = "seed-int32-overflow"
    code = "GL102"
    description = ("Python-int seed arithmetic fed to PRNGKey (or cast to "
                   "int32) can wrap; use fold_in or mask with 0xFFFFFFFF")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.call_name(node)
            if name in ("jax.random.PRNGKey", "jax.random.key"):
                if node.args and isinstance(node.args[0], ast.BinOp) \
                        and not _is_masked(node.args[0]):
                    yield self.finding(
                        ctx, node.args[0],
                        "seed arithmetic inside PRNGKey can wrap int32; use "
                        "jax.random.fold_in(PRNGKey(seed), i) or mask with "
                        "& 0xFFFFFFFF")
            elif name in ("numpy.int32", "jax.numpy.int32"):
                if node.args and _mentions_seedish(node.args[0]):
                    yield self.finding(
                        ctx, node,
                        "int32 cast of a seed expression truncates host "
                        "seed arithmetic; keep seeds int64 and mask "
                        "explicitly (& 0xFFFFFFFF) at key-construction time")
