"""Traced-code purity rules.

GL103 host-sync-in-jit: host synchronization inside code reachable from a
jit/scan/vmap root — ``.item()``, numpy-module calls on traced values,
``float()/int()/bool()`` of non-constants, ``pure_callback``/``io_callback``
— either fails tracing outright or silently forces a device round-trip per
step.  Sanctioned escapes carry a ``# lint: host-sync-ok`` marker (e.g. the
oracle's deliberate pure_callback fallback).

GL109 jit-per-call: ``jax.jit(f)`` constructed and invoked inside the same
(non-cached) function scope builds a fresh compilation cache entry per
call — the retrace-churn bug the ``lru_cache``d factory pattern in
core/explorer.py exists to avoid.  AOT chains (``jax.jit(f).lower(...)``)
are exempt.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from ..core import FileContext, Finding, Rule

HOST_SYNC_MARKER = "lint: host-sync-ok"

_JIT_DECORATORS = {"jax.jit", "jax.vmap", "jax.pmap"}
_JIT_TAKERS = {"jax.jit", "jax.vmap", "jax.pmap", "jax.grad",
               "jax.value_and_grad", "jax.checkpoint", "jax.remat",
               "jax.lax.scan", "jax.lax.while_loop", "jax.lax.cond",
               "jax.lax.fori_loop", "jax.lax.map",
               "jax.experimental.pallas.pallas_call"}
_CALLBACKS = {"jax.pure_callback", "jax.experimental.io_callback",
              "jax.experimental.host_callback.call"}


def _decorator_name(ctx: FileContext, dec: ast.AST) -> Optional[str]:
    """Resolve a decorator, looking through functools.partial(...)."""
    if isinstance(dec, ast.Call):
        name = ctx.call_name(dec)
        if name in ("functools.partial", "partial") and dec.args:
            return ctx.resolve(dec.args[0])
        return name
    return ctx.resolve(dec)


def _own_body(fn) -> Iterator[ast.AST]:
    """Nodes of `fn`'s body excluding nested function/class scopes."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                continue
            stack.append(child)


class HostSyncInJit(Rule):
    name = "host-sync-in-jit"
    code = "GL103"
    description = ("host sync (.item()/numpy call/float()/pure_callback) "
                   "reachable from a jit/scan/vmap root without the "
                   "'# lint: host-sync-ok' marker")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        defs: Dict[str, ast.AST] = {}
        for fn in ctx.functions():
            defs.setdefault(fn.name, fn)

        roots: Set[str] = set()
        for fn in ctx.functions():
            for dec in fn.decorator_list:
                if _decorator_name(ctx, dec) in _JIT_DECORATORS:
                    roots.add(fn.name)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and \
                    ctx.call_name(node) in _JIT_TAKERS:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in defs:
                        roots.add(arg.id)

        # intra-module reachability over simple-name calls
        reachable = set(roots)
        frontier = list(roots)
        while frontier:
            fn = defs.get(frontier.pop())
            if fn is None:
                continue
            for node in _own_body(fn):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in defs and node.func.id not in reachable:
                    reachable.add(node.func.id)
                    frontier.append(node.func.id)

        for name in sorted(reachable):
            fn = defs[name]
            static_params = {
                a.arg for a in list(fn.args.args) + list(fn.args.kwonlyargs)
                if isinstance(a.annotation, ast.Name)
                and a.annotation.id in ("int", "float", "bool")
            } if hasattr(fn, "args") else set()
            for node in _own_body(fn):
                msg = self._host_sync(ctx, node, static_params)
                if msg and not ctx.line_has_marker(node.lineno,
                                                  HOST_SYNC_MARKER):
                    yield self.finding(
                        ctx, node,
                        f"{msg} inside jit-reachable '{name}'; hoist to the "
                        f"host or mark the sanctioned fallback with "
                        f"'# {HOST_SYNC_MARKER}'")

    def _host_sync(self, ctx: FileContext, node: ast.AST,
                   static_params: Set[str] = frozenset()) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        name = ctx.call_name(node)
        if name in _CALLBACKS:
            return f"{name.rsplit('.', 1)[1]}() host escape"
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            return ".item() device sync"
        if name and name.startswith("numpy.") and any(
                not isinstance(a, ast.Constant) for a in node.args):
            return f"numpy call {name}"
        if name in ("float", "int", "bool") and node.args and \
                self._non_static(node.args[0], static_params):
            return f"{name}() of a traced value"
        return None

    @staticmethod
    def _non_static(arg: ast.AST,
                    static_params: Set[str] = frozenset()) -> bool:
        if isinstance(arg, ast.Constant):
            return False
        # an int/float/bool-annotated parameter is a static Python scalar
        # by signature (shape dims fed to block pickers, etc.)
        for sub in ast.walk(arg):
            if isinstance(sub, ast.Name) and sub.id in static_params:
                return False
        for sub in ast.walk(arg):
            # shape/dtype/len() are static under trace — not a sync
            if isinstance(sub, ast.Attribute) and sub.attr in ("shape",
                                                               "ndim",
                                                               "size",
                                                               "dtype"):
                return False
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Name) and sub.func.id == "len":
                return False
        return True


class JitPerCall(Rule):
    name = "jit-per-call"
    code = "GL109"
    description = ("jax.jit(...) built and invoked inside the same "
                   "non-lru_cached function retraces on every call")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.functions():
            if any(_decorator_name(ctx, d) in
                   ("functools.lru_cache", "functools.cache", "lru_cache",
                    "cache") for d in fn.decorator_list):
                continue
            yield from self._check_scope(ctx, fn)

    def _check_scope(self, ctx: FileContext, fn) -> Iterator[Finding]:
        jit_names: Set[str] = set()
        for node in _own_body(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    ctx.call_name(node.value) in ("jax.jit", "jax.pmap"):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jit_names.add(t.id)
            if not isinstance(node, ast.Call):
                continue
            # direct jax.jit(f)(args) — exempt AOT .lower()/.compile()
            if isinstance(node.func, ast.Call) and \
                    ctx.call_name(node.func) in ("jax.jit", "jax.pmap"):
                yield self.finding(
                    ctx, node,
                    "jax.jit(...) invoked where it is built: every call "
                    "retraces; hoist behind an lru_cache'd factory or to "
                    "module scope")
            if isinstance(node.func, ast.Name) and node.func.id in jit_names:
                yield self.finding(
                    ctx, node,
                    f"'{node.func.id}' is a jax.jit result built in this "
                    f"same call; hoist the jit behind an lru_cache'd "
                    f"factory so the cache survives across calls")
