"""GL104 pspec-unknown-axis: every ``PartitionSpec`` axis name must exist
in the repo's meshes.  The only axes this codebase ever creates are
``pod``/``data``/``model`` (train/shardings.py) and the serve-side task
mesh reuses ``data`` (core/shard.py); a spec naming anything else shards
over a nonexistent axis and jax raises — or worse, a typo'd
``PartitionSpec(())`` entry silently replicates what was meant to be
sharded (the PR-6 bug).  Flags unknown axis strings, empty-tuple entries,
and the same axis used twice in one spec.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from ..core import FileContext, Finding, Rule

#: the only mesh axis names constructed anywhere in this repo
#: (train/shardings.py make_mesh and core/shard.py task mesh)
KNOWN_AXES = {"pod", "data", "model"}

_PSPEC_NAMES = {"jax.sharding.PartitionSpec",
                "jax.experimental.pjit.PartitionSpec",
                "PartitionSpec", "P"}


class PSpecUnknownAxis(Rule):
    name = "pspec-unknown-axis"
    code = "GL104"
    description = ("PartitionSpec axis not in the repo meshes "
                   "(pod/data/model), empty-tuple entry, or duplicate axis")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        pspec_locals = self._pspec_aliases(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.call_name(node)
            if name not in _PSPEC_NAMES and name not in pspec_locals:
                continue
            seen: Set[str] = set()
            for arg in node.args:
                yield from self._check_entry(ctx, arg, seen)

    def _pspec_aliases(self, ctx: FileContext) -> Set[str]:
        """Module-level `P = jax.sharding.PartitionSpec` style aliases."""
        out: Set[str] = set()
        for node in ctx.tree.body:
            if isinstance(node, ast.Assign) and \
                    ctx.resolve(node.value) in _PSPEC_NAMES:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
        return out

    def _check_entry(self, ctx: FileContext, arg: ast.AST,
                     seen: Set[str]) -> Iterator[Finding]:
        if isinstance(arg, ast.Constant):
            if isinstance(arg.value, str):
                yield from self._check_axis(ctx, arg, arg.value, seen)
        elif isinstance(arg, ast.Tuple):
            if not arg.elts:
                yield self.finding(
                    ctx, arg,
                    "empty-tuple PartitionSpec entry silently replicates "
                    "this dimension; write None for intentional replication")
            for el in arg.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    yield from self._check_axis(ctx, el, el.value, seen)

    def _check_axis(self, ctx: FileContext, node: ast.AST, axis: str,
                    seen: Set[str]) -> Iterator[Finding]:
        if axis not in KNOWN_AXES:
            yield self.finding(
                ctx, node,
                f"axis '{axis}' is not a mesh axis of this repo "
                f"(known: {', '.join(sorted(KNOWN_AXES))})")
        elif axis in seen:
            yield self.finding(
                ctx, node,
                f"axis '{axis}' appears twice in one PartitionSpec")
        seen.add(axis)
