"""GL108 pallas-blockspec: every dimension of a Pallas ``BlockSpec`` block
shape must be a compile-time constant the kernel's grid math can divide —
an int literal, or a name produced by the padding helpers (``_pick`` /
``_round_up``-family, which round to a power-of-two block and pad the
operand).  A dim lifted straight off ``x.shape`` re-specializes the kernel
for every new input shape and, off the pow2 grid, silently falls back to
the slow path (the fused_mlp ``_pick`` redesign exists to prevent exactly
this).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set

from ..core import FileContext, Finding, Rule

_PAD_HELPER = re.compile(r"(^|\.)(_?pick(_block)?|_?round_up|_?next_pow2)$")


def _shape_derived(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "shape":
            return True
    return False


class PallasBlockSpec(Rule):
    name = "pallas-blockspec"
    code = "GL108"
    description = ("BlockSpec dim taken from a runtime .shape instead of an "
                   "int constant or the _pick/_round_up padding helpers")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.functions():
            bindings = self._bindings(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = ctx.call_name(node)
                if not name or not name.endswith("BlockSpec"):
                    continue
                shape_arg = node.args[0] if node.args else None
                for kw in node.keywords:
                    if kw.arg == "block_shape":
                        shape_arg = kw.value
                if isinstance(shape_arg, ast.Tuple):
                    for el in shape_arg.elts:
                        yield from self._check_dim(ctx, el, bindings)

    def _bindings(self, fn) -> Dict[str, List[ast.Assign]]:
        """name -> assignments binding it (in source order), this scope."""
        out: Dict[str, List[ast.Assign]] = {}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            for t in node.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        out.setdefault(sub.id, []).append(node)
        return out

    def _check_dim(self, ctx: FileContext, el: ast.AST,
                   bindings: Dict[str, List[ast.Assign]]) -> Iterator[Finding]:
        if isinstance(el, ast.Constant):
            return
        if _shape_derived(el):
            yield self.finding(
                ctx, el,
                "BlockSpec dim computed from a runtime .shape; route it "
                "through _pick/_round_up so the block is a padded pow2 "
                "constant")
            return
        if isinstance(el, ast.Name):
            binding = self._latest_binding(el, bindings)
            if binding is None:
                return      # parameter / outer-scope: not provably bad
            if self._is_padded(ctx, binding):
                return
            if self._binds_from_shape(el.id, binding):
                yield self.finding(
                    ctx, el,
                    f"BlockSpec dim '{el.id}' is unpacked from a runtime "
                    f".shape; route it through _pick/_round_up so the "
                    f"block is a padded pow2 constant")

    def _latest_binding(self, el: ast.Name,
                        bindings: Dict[str, List[ast.Assign]]
                        ) -> Optional[ast.Assign]:
        prior = [b for b in bindings.get(el.id, ())
                 if b.lineno <= el.lineno]
        return prior[-1] if prior else None

    def _is_padded(self, ctx: FileContext, binding: ast.Assign) -> bool:
        v = binding.value
        if isinstance(v, ast.Constant):
            return True
        if isinstance(v, ast.Call):
            name = ctx.call_name(v)
            return bool(name and _PAD_HELPER.search(name))
        return False

    def _binds_from_shape(self, name: str, binding: ast.Assign) -> bool:
        return _shape_derived(binding.value)
