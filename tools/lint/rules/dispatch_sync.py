"""GL112 host-sync-in-dispatch: device->host materialization inside the
batched dispatch entry points.

The bug class: ``enumerate_candidates_batch`` used to read back
``np.asarray(total)`` mid-dispatch to pick the padded candidate width
(explorer.py, pre-fused-route), stalling the device pipeline in the
middle of what serving treats as one uninterrupted program.  The fused
tiled route computes every extent on device; this rule keeps host reads
(``np.asarray``/``np.array``/``jax.device_get``/``.item()``, or
``int()``/``float()`` wrapping one of them) out of dispatch bodies —
functions named ``explore_batch`` or ``execute_batch`` plus everything
they reach through same-module simple-name calls.  Host tails that run
*after* the dispatch returns (e.g. ``selections_from_winners`` in
core/selector) live in other modules and are deliberately out of scope.

Sanctioned reads (e.g. a result consumed on the host right at the entry
point by design) carry a ``# lint: dispatch-sync-ok`` marker.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from ..core import FileContext, Finding, Rule
from .jit_purity import _own_body

DISPATCH_SYNC_MARKER = "lint: dispatch-sync-ok"

#: dispatch entry points: the engines' batched explore and the serve
#: dispatch path (DSEServer.execute_batch)
_DISPATCH_ROOTS = {"explore_batch", "execute_batch"}
_MATERIALIZERS = {"numpy.asarray", "numpy.array", "jax.device_get"}


class HostSyncInDispatch(Rule):
    name = "host-sync-in-dispatch"
    code = "GL112"
    description = ("device->host read (np.asarray/.item()/device_get) "
                   "inside explore_batch/execute_batch-reachable dispatch "
                   "code without the '# lint: dispatch-sync-ok' marker")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        defs: Dict[str, ast.AST] = {}
        for fn in ctx.functions():
            defs.setdefault(fn.name, fn)

        reachable: Set[str] = {n for n in _DISPATCH_ROOTS if n in defs}
        frontier = list(reachable)
        while frontier:
            fn = defs[frontier.pop()]
            for node in _own_body(fn):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in defs and node.func.id not in reachable:
                    reachable.add(node.func.id)
                    frontier.append(node.func.id)

        for name in sorted(reachable):
            seen_lines: Set[int] = set()
            for node in _own_body(defs[name]):
                msg = self._host_read(ctx, node)
                if msg is None or node.lineno in seen_lines:
                    continue
                seen_lines.add(node.lineno)   # int(np.asarray(x)) fires once
                if not ctx.line_has_marker(node.lineno, DISPATCH_SYNC_MARKER):
                    yield self.finding(
                        ctx, node,
                        f"{msg} inside dispatch-reachable '{name}': the "
                        f"batched route must stay one uninterrupted device "
                        f"program — compute the extent on device (see "
                        f"core/fused_select) or mark a sanctioned read "
                        f"with '# {DISPATCH_SYNC_MARKER}'")

    def _host_read(self, ctx: FileContext, node: ast.AST) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        name = ctx.call_name(node)
        if name in _MATERIALIZERS and any(
                not isinstance(a, ast.Constant) for a in node.args):
            return f"{name} device->host materialization"
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args:
            return ".item() device sync"
        if name in ("int", "float") and node.args and any(
                isinstance(sub, ast.Call)
                and ctx.call_name(sub) in _MATERIALIZERS
                for sub in ast.walk(node.args[0])):
            return f"{name}() of a device->host read"
        return None
