"""GL110 nan-transparent-violation: the PR-3 scoring bug class.  A cost
model can emit NaN (log of a non-positive intermediate, division by a
zero bandwidth); NaN compares false against every threshold, so a
violation/satisfaction function without an explicit finiteness guard
scores an invalid design as *feasible* and the DSE happily selects it.
Any function whose name says it judges violation/satisfaction/feasibility
and that computes a comparison or margin must reference ``isfinite`` /
``isnan`` / ``nan_to_num`` somewhere in its body (see
``core/selector.py:is_satisfied`` — "non-finite metrics never satisfy").
"""
from __future__ import annotations

import ast
import re
from typing import Iterator

from ..core import FileContext, Finding, Rule

_JUDGE_NAME = re.compile(r"viol|satisf|feasib", re.IGNORECASE)
_GUARD_NAME = re.compile(r"isfinite|isnan|isinf|nan_to_num|notnan",
                         re.IGNORECASE)


class NanTransparentViolation(Rule):
    name = "nan-transparent-violation"
    code = "GL110"
    description = ("violation/satisfaction scoring without an isfinite/"
                   "isnan guard treats NaN metrics as feasible")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.functions():
            if not _JUDGE_NAME.search(fn.name):
                continue
            scores, guarded = False, False
            for node in ast.walk(fn):
                if isinstance(node, ast.Compare) or (
                        isinstance(node, ast.BinOp)
                        and isinstance(node.op, ast.Sub)):
                    scores = True
                if isinstance(node, (ast.Name, ast.Attribute)):
                    ident = (node.id if isinstance(node, ast.Name)
                             else node.attr)
                    if _GUARD_NAME.search(ident):
                        guarded = True
            if scores and not guarded:
                yield self.finding(
                    ctx, fn,
                    f"'{fn.name}' judges feasibility but never checks "
                    f"isfinite/isnan: NaN metrics compare false against "
                    f"every threshold and score as satisfied; guard like "
                    f"core/selector.py:is_satisfied")
