"""GL107 overbroad-except: exception hygiene, strictest where it matters.

Everywhere: bare ``except:`` and ``except Exception: pass``-style silent
swallows are flagged — they eat KeyboardInterrupt/corruption signals or
hide the first failure of a cascade.

In the *dispatch and checkpoint paths* (any file under ``serve/`` or
``checkpoint/``, or named ``*dispatch*``): ``except Exception`` must
either bind the exception (so it can be recorded in the response/stats —
the serving tier's fault-isolation contract) or re-raise after cleanup.
An unbound, non-reraising broad handler there turns a real fault into a
silent wrong answer.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator

from ..core import FileContext, Finding, Rule

_STRICT_PATH = re.compile(r"(/|^)(serve|checkpoint)(/|$)|dispatch")
_BROAD = {"Exception", "BaseException"}


def _is_broad(node: ast.ExceptHandler) -> bool:
    return isinstance(node.type, ast.Name) and node.type.id in _BROAD


def _reraises(node: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(node))


def _swallows_silently(node: ast.ExceptHandler) -> bool:
    return all(isinstance(n, (ast.Pass, ast.Continue)) for n in node.body)


class OverbroadExcept(Rule):
    name = "overbroad-except"
    code = "GL107"
    description = ("bare except, silent broad swallow, or (in serve/"
                   "checkpoint paths) except Exception that neither binds "
                   "nor re-raises")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        strict = bool(_STRICT_PATH.search(ctx.path))
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.finding(
                    ctx, node,
                    "bare 'except:' also catches KeyboardInterrupt/"
                    "SystemExit; catch Exception at most, and bind it")
            elif _is_broad(node) and _swallows_silently(node):
                yield self.finding(
                    ctx, node,
                    f"'except {node.type.id}: pass' swallows every failure "
                    f"silently; bind it and record/log, or narrow the type")
            elif strict and _is_broad(node) and node.name is None \
                    and not _reraises(node):
                yield self.finding(
                    ctx, node,
                    f"broad 'except {node.type.id}:' in a dispatch/"
                    f"checkpoint path neither binds the error nor "
                    f"re-raises; bind it ('as e') and record it so the "
                    f"fault surfaces in responses/stats")
