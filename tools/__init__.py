"""In-repo developer tooling (static analysis, CI guards)."""
