"""Table-5 comparison harness: every DSE method, one shared experiment.

Reproduces the paper's headline experiment (Table 5, Fig. 5): train GANDSE
and the learned baselines on ONE shared dataset per design model, run the
same DSE task set through every method via the ``DSEMethod`` protocol, and
report satisfied counts, improvement ratio, DSE time, and candidate counts
side by side.

Fairness rules:

- every method explores the same tasks with the same seed;
- RandomSearch (the sanity floor, not in the paper's table) is budget
  -matched to GANDSE: its sample count is set to GANDSE's mean candidate
  count, so "GANDSE beats random search" is an equal-evaluation-budget
  claim;
- all methods serve the batch through their device-resident
  ``explore_tasks`` route (sequential host fallback for models without a
  jnp oracle), so DSE times compare the same serving discipline.

  PYTHONPATH=src python experiments/run_comparison.py [--quick]
      [--models dnnweaver im2col tpu_mesh]

Writes ``results/comparison_<model>.json`` per design model plus the
combined ``results/comparison.json``.  Reduced-scale defaults for CPU; the
paper scale (11-14 layers x 2048 neurons) is documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.baselines.drl import PolicyGradientDRL
from repro.baselines.mlp import LargeMLP
from repro.baselines.random_search import RandomSearch
from repro.baselines.sa import SimulatedAnnealing
from repro.core.dse_api import DSEMethod, GANDSE, summarize
from repro.core.explorer import ExplorerConfig
from repro.core.gan import GANConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel

MODELS = {
    "dnnweaver": DnnWeaverModel,
    "im2col": Im2colModel,
    "tpu_mesh": TpuMeshModel,
}

#: Per-design-model exploration threshold (a deployment knob, §7.1.3:
#: higher-dimension/higher-entropy spaces need a sharper cut or the
#: candidate budget explodes) and training length (the tpu_mesh divisibility
#: structure needs more epochs to concentrate at CPU scale).
MODEL_PRESETS = {
    "dnnweaver": dict(threshold=0.2, iters_mult=1, data_mult=1),
    "im2col": dict(threshold=0.3, iters_mult=1, data_mult=1),
    "tpu_mesh": dict(threshold=0.4, iters_mult=6, data_mult=2),
}

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


@dataclasses.dataclass(frozen=True)
class Scale:
    """Experiment scale (env-overridable, like benchmarks/common.py)."""

    n_data: int = int(os.environ.get("REPRO_GAN_DATA", 8000))
    n_tasks: int = int(os.environ.get("REPRO_GAN_TASKS", 200))
    iters: int = int(os.environ.get("REPRO_GAN_ITERS", 8))
    layers: int = int(os.environ.get("REPRO_GAN_LAYERS", 3))
    neurons: int = int(os.environ.get("REPRO_GAN_NEURONS", 256))
    lr: float = float(os.environ.get("REPRO_GAN_LR", 1e-4))
    w_critic: float = 0.5
    #: Pareto-adjacent objectives (§7.4 "hard" setting).  This is both the
    #: regime the paper's headline claim targets and the training
    #: distribution itself (dataset rows pair each witness config with its
    #: own exact metrics); loose slack hands budget-matched random search a
    #: dense satisfying region that masks conditioning quality entirely.
    slack: Tuple[float, float] = (1.0, 1.0)

    @staticmethod
    def quick() -> "Scale":
        """Smoke scale (tier-1 / CI): fewer tasks.  The GAN stays at the
        standing reduced scale (3x256) — an undertrained G inflates its own
        candidate budget, which hands budget-matched random search enough
        lottery tickets to mask real regressions in the comparison."""
        return Scale(n_tasks=50)


def build_methods(model, scale: Scale) -> List[DSEMethod]:
    """Every method of the comparison, untrained.  RandomSearch comes last
    so its budget can be matched to GANDSE's measured candidate count."""
    threshold = MODEL_PRESETS[model.name]["threshold"]
    explorer_cfg = ExplorerConfig(prob_threshold=threshold)
    gan_cfg = GANConfig(n_net=model.net_space.n_dims,
                        w_critic=scale.w_critic).scaled(
        layers=scale.layers, neurons=scale.neurons, lr=scale.lr,
        batch_size=512)
    return [
        GANDSE(model, gan_cfg, explorer_cfg),
        # parameter-matched to GAN G+D: ~2x layers at the same width, and
        # the same exploration threshold as G (fair thresholded outputs)
        LargeMLP(model, hidden_layers=2 * scale.layers,
                 neurons=scale.neurons, lr=scale.lr,
                 explorer_cfg=explorer_cfg),
        PolicyGradientDRL(model),
        SimulatedAnnealing(model),
        RandomSearch(model),
    ]


def run_comparison(model_name: str, scale: Optional[Scale] = None,
                   seed: int = 0, results_dir: str = RESULTS_DIR) -> Dict:
    """Train all methods on one shared dataset, explore one shared task
    set, and emit the Table-5-style rows for `model_name`."""
    scale = scale or Scale()
    model = MODELS[model_name]()
    preset = MODEL_PRESETS[model_name]
    ds = generate_dataset(model, scale.n_data * preset["data_mult"],
                          seed=seed)
    tasks = generate_tasks(model, scale.n_tasks, seed=seed + 1,
                           slack=scale.slack)

    rows = []
    gandse_budget = None
    for method in build_methods(model, scale):
        if method.method_name == "RandomSearch" and gandse_budget:
            method.n_samples = gandse_budget        # equal candidate budget
        t0 = time.time()
        iters = scale.iters * preset["iters_mult"]
        # DRL needs more iterations per unit progress: one iter = one
        # policy-gradient rollout batch, not one dataset epoch
        if method.method_name == "DRL":
            iters *= 4
        method.train(n_data=scale.n_data, iters=iters, seed=seed, ds=ds)
        train_s = time.time() - t0
        # warmup pass compiles every route so the timed run reports warm
        # serving time, not one-off XLA compiles amortized over the batch
        # (deterministic: same seed -> identical selections)
        method.explore_tasks(tasks, seed=seed + 2)
        results = method.explore_tasks(tasks, seed=seed + 2)
        row = summarize(results)
        row.update(
            method=method.method_name,
            train_time_s=round(train_s, 2),
            satisfied_rate=row["n_satisfied"] / max(row["n_tasks"], 1),
        )
        rows.append(row)
        if method.method_name == "GANDSE":
            gandse_budget = max(1, int(round(row["n_candidates"])))
        print(f"[comparison:{model_name}] {row['method']:12s} "
              f"sat={row['n_satisfied']}/{row['n_tasks']} "
              f"impr={row['improvement_ratio']:.4f} "
              f"dse={row['dse_time_s']*1e3:.2f}ms "
              f"cand={row['n_candidates']:.1f} train={train_s:.1f}s",
              flush=True)

    report = {
        "model": model_name,
        "scale": dataclasses.asdict(scale),
        "seed": seed,
        "rows": rows,
    }
    os.makedirs(results_dir, exist_ok=True)
    with open(os.path.join(results_dir, f"comparison_{model_name}.json"),
              "w") as f:
        json.dump(report, f, indent=1)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", nargs="+", default=sorted(MODELS),
                    choices=sorted(MODELS))
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale: fewer tasks (CI); nets and dataset "
                         "stay at the full reduced scale (see Scale.quick)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    scale = Scale.quick() if args.quick else Scale()

    combined = {}
    for name in args.models:
        combined[name] = run_comparison(name, scale, seed=args.seed)
    with open(os.path.join(RESULTS_DIR, "comparison.json"), "w") as f:
        json.dump(combined, f, indent=1)

    # the acceptance bar of the reproduction: GANDSE finds at least as many
    # satisfying designs as budget-matched random search, on every model
    ok = True
    for name, report in combined.items():
        by = {r["method"]: r for r in report["rows"]}
        g, r = by["GANDSE"], by["RandomSearch"]
        verdict = "ok" if g["satisfied_rate"] >= r["satisfied_rate"] else "FAIL"
        if verdict == "FAIL":
            ok = False
        print(f"[comparison:{name}] GANDSE {g['satisfied_rate']:.2f} vs "
              f"RandomSearch {r['satisfied_rate']:.2f} "
              f"(budget {r['n_candidates']:.0f}) -> {verdict}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
