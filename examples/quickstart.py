"""Quickstart: the four GANDSE phases end-to-end on the DnnWeaver template.

  PYTHONPATH=src python examples/quickstart.py

Trains the GAN-based design explorer (reduced scale for CPU), then runs a
DSE task — "accelerator for this conv layer with latency <= LO and power
<= PO" — and emits the selected configuration artifact (the stand-in for
the paper's RTL generation phase).
"""
import json

import numpy as np

from repro.core.dse_api import GANDSE, parse_network, summarize
from repro.core.gan import GANConfig
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel


def main():
    # ---- training phase (once per design template) -------------------------
    model = DnnWeaverModel()
    gan_cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=1.0).scaled(
        layers=3, neurons=256, batch_size=512, lr=1e-4)
    gandse = GANDSE(model, gan_cfg)
    print("training the design explorer (reduced scale)...")
    gandse.train(n_data=6000, iters=6, log_every=2)

    # ---- parsing phase ------------------------------------------------------
    net = parse_network(
        {"IC": 64, "OC": 128, "OW": 32, "OH": 32, "KW": 3, "KH": 3}, model)

    # pick achievable objectives: evaluate a random config and relax 1.5x
    rng = np.random.default_rng(0)
    probe = model.space.sample_indices(rng, 64)
    lat, pw = model.evaluate_indices(np.repeat(net[None], 64, 0), probe)
    ok = np.isfinite(lat)
    lo, po = float(np.median(lat[ok]) * 1.2), float(np.median(pw[ok]) * 1.2)
    print(f"objectives: latency <= {lo:.4g}s, power <= {po:.4g}W")

    # ---- exploration phase ---------------------------------------------------
    result = gandse.explore(net, lo, po)
    print(f"satisfied={result.satisfied} "
          f"latency={result.selection.latency:.4g}s "
          f"power={result.selection.power:.4g}W "
          f"improvement_ratio={result.improvement_ratio} "
          f"dse_time={result.dse_seconds*1e3:.0f}ms "
          f"candidates={result.selection.n_candidates}")

    # ---- implementation phase ------------------------------------------------
    if result.satisfied:
        artifact = gandse.emit_config(result)
        print(json.dumps(artifact, indent=1))

    # batch evaluation across random tasks: explore_tasks serves the whole
    # batch device-resident in one dispatch chain (see README "Serving").
    # The first batch pays the one-time jit compiles; the second shows the
    # warm steady-state serving latency.
    tasks = generate_tasks(model, 50, seed=1)
    print("batch (cold, incl. jit):", summarize(gandse.explore_tasks(tasks)))
    print("batch (warm):           ", summarize(gandse.explore_tasks(tasks)))


if __name__ == "__main__":
    main()
