"""Beyond-paper example: GAN-DSE searching THIS framework's parallelism
design space (pods x dp x tp x microbatch x remat x dtype x compression)
for a target workload, with the TPU roofline as the design model.

  PYTHONPATH=src python examples/mesh_dse.py
"""
import json

import numpy as np

from repro.core.dse_api import GANDSE
from repro.core.gan import GANConfig
from repro.design_models.tpu_mesh import TpuMeshModel


def main():
    model = TpuMeshModel()
    cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=0.5).scaled(
        layers=3, neurons=256, batch_size=512, lr=1e-4)
    gandse = GANDSE(model, cfg)
    print("training mesh-DSE explorer...")
    gandse.train(n_data=8000, iters=8, log_every=4)

    # workload: qwen3-14b-like training job (40L x 5120, seq 4096, batch 256)
    net = model.net_space.indices_from_values(
        np.array([[40., 5120., 3., 4096., 256., 131072.]]))[0]

    # objectives: step_time <= 5 s at <= 150 kW cluster power
    result = gandse.explore(net, 5.0, 150e3)
    print(f"satisfied={result.satisfied} "
          f"step_time={result.selection.latency:.3f}s "
          f"power={result.selection.power/1e3:.1f}kW "
          f"dse_time={result.dse_seconds*1e3:.0f}ms")
    if result.satisfied:
        art = gandse.emit_config(result)
        print(json.dumps(art, indent=1))
        c = art["config"]
        chips = int(c["PODS"] * c["DP"] * c["TP"])
        print(f"-> launch config: {int(c['PODS'])} pod(s) x "
              f"(data={int(c['DP'])}, model={int(c['TP'])}) = {chips} chips, "
              f"microbatch={int(c['MICRO'])}, remat={bool(c['REMAT'])}, "
              f"param_bytes={int(c['BYTES_P'])}, "
              f"dcn_compression={int(c['COMPRESS'])}x")


if __name__ == "__main__":
    main()
