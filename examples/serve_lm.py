"""Serving example: continuous-batching engine over a reduced gemma3
(5:1 local:global attention) with mixed-length requests.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.launch.serve import Engine, Request
from repro.models import base as MB


def main():
    m = configs.get_reduced("gemma3-1b")
    params = MB.init_params(jax.random.PRNGKey(0), m)
    eng = Engine(m, params, batch_slots=4, cache_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for r in range(12):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(rid=r, prompt=rng.integers(0, m.vocab, plen).tolist(),
                           max_new=int(rng.integers(8, 24))))
    iters = eng.run()
    toks = sum(len(r.out) for r in eng.finished)
    dt = time.time() - t0
    print(f"served {len(eng.finished)} requests, {toks} tokens, "
          f"{iters} engine iterations, {toks/dt:.1f} tok/s")
    for r in eng.finished[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
