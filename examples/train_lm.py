"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the synthetic pipeline with checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]

--small shrinks to the reduced config for a fast demo; the default builds
a ~100M-param qwen3-family model (12L x 768).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.models import base as MB
from repro.models.builders import decoder_arch
from repro.train import step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.small:
        m = decoder_arch("demo-lm", "dense", 2, 128, 4, 2, 256, 2048,
                         qk_norm=True, tied=True)
    else:
        # ~100M params: 12L x d768 (GQA kv=4) x ff2048, 32k vocab
        m = decoder_arch("demo-lm-100m", "dense", 12, 768, 12, 4, 2048,
                         32768, qk_norm=True, tied=True)

    mesh = make_host_mesh()
    params = MB.init_params(jax.random.PRNGKey(0), m)
    print(f"model {m.name}: {MB.param_count(params)/1e6:.1f}M params")
    step_fn, optim = TS.make_train_step(m, lr=3e-4, remat=False, mesh=mesh)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    opt = optim.init(params)

    stream = SyntheticStream(DataConfig(vocab=m.vocab, seq_len=args.seq,
                                        global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt_dir, keep_last_n=2)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        toks, labels = stream.batch(step)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            tput = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step={step:4d} loss={loss:.4f} tok/s={tput:,.0f}",
                  flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt})
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
