"""Attention: GQA, sliding-window, blocked (flash-style) XLA implementation.

Three execution paths:
  * ``flash_attention_xla`` — pure-XLA blocked attention with online softmax
    (lax.scan over query/key blocks, O(S·block) memory).  This is the path
    the multi-pod dry-run lowers; for sliding-window attention only the
    in-band KV blocks are visited (truly sub-quadratic FLOPs).
  * Pallas kernel (kernels/flash_attention.py) — TPU target, selected with
    ``use_pallas=True`` (validated in interpret mode on CPU).
  * ``decode_attention`` — single-token query against a (possibly ring-
    buffered) KV cache.

Shapes: q (B, S, H, D); k, v (B, S, Hkv, D); H = Hkv * G.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_heads(q, n_kv: int):
    """(B, S, H, D) -> (B, S, Hkv, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jnp.ndarray:
    """Unblocked reference (used by tests & tiny smoke configs).

    q_offset: absolute position of q[0] (for cached prefill continuation).
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    qg = _split_heads(q, hkv)                              # (B,Sq,Hkv,G,D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _attn_block(qg, kb, vb, qpos, kpos, causal, window):
    """One (q-block, kv-block) tile with masking; returns (s, m, raw p, pv)."""
    d = qg.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        kb.astype(jnp.float32)) * (1.0 / jnp.sqrt(d))
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
    return jnp.where(mask[None, None, None], scores, NEG_INF)


def flash_attention_xla(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Blocked attention with online softmax (flash algorithm in XLA).

    For ``window`` set, only ceil((window+q_block)/kv_block) KV blocks are
    visited per query block via dynamic_slice -> sub-quadratic compute.

    Differentiation goes through a custom VJP that RECOMPUTES the score
    tiles in the backward pass (true flash backward): without it, autodiff
    through the forward scan saves O(S^2) probability matrices per layer —
    the dominant HBM-traffic term found by the §Perf profile.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    if sq < q_block or sq % q_block or sk % kv_block:
        return attention_reference(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return _flash_custom(q, k, v, causal, window, q_block, kv_block, q_offset)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_custom(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                             q_offset)
    return out


def _flash_custom_fwd(q, k, v, causal, window, q_block, kv_block, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block,
                               q_offset)
    return out, (q, k, v, out, lse)


def _flash_custom_bwd(causal, window, q_block, kv_block, q_offset, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    nq = sq // q_block

    # D_i = rowsum(dout * out)  (B, Sq, H)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)

    def reshape_q(t):                                  # (nq,B,Bq,Hkv,G,D)
        return jnp.moveaxis(
            _split_heads(t, hkv).reshape(b, nq, q_block, hkv, g, d), 1, 0)

    qb_all = reshape_q(q)
    dob_all = reshape_q(dout)
    lse_b = jnp.moveaxis(                              # (nq,B,Hkv,G,Bq)
        lse.reshape(b, nq, q_block, hkv, g), 1, 0).transpose(0, 1, 3, 4, 2)
    del_b = jnp.moveaxis(
        delta.reshape(b, nq, q_block, hkv, g), 1, 0).transpose(0, 1, 3, 4, 2)

    span = sk
    if window is not None:
        span = min(((window + q_block + kv_block - 1) // kv_block) * kv_block,
                   sk)

    def q_body(carry, inp):
        dk_acc, dv_acc = carry
        qi, qblk, dob, lse_i, del_i = inp
        qpos = q_offset + qi * q_block + jnp.arange(q_block)
        start = (jnp.clip(q_offset + (qi + 1) * q_block - span, 0, sk - span)
                 if window is not None else 0)
        kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kpos = start + jnp.arange(span)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qblk.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        mask = jnp.ones((q_block, span), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        p = jnp.where(mask[None, None, None],
                      jnp.exp(s - lse_i[..., None]), 0.0)
        dv_blk = jnp.einsum("bkgqs,bqkgd->bskd", p, dob.astype(jnp.float32))
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dob.astype(jnp.float32),
                        vb.astype(jnp.float32))
        ds = p * (dp - del_i[..., None])
        dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                            kb.astype(jnp.float32)) * scale
        dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                            qblk.astype(jnp.float32)) * scale
        upd = lambda acc, blk: jax.lax.dynamic_update_slice_in_dim(
            acc, jax.lax.dynamic_slice_in_dim(acc, start, span, 1) + blk,
            start, axis=1)
        return (upd(dk_acc, dk_blk), upd(dv_acc, dv_blk)), dq_blk

    dk0 = jnp.zeros((b, sk, hkv, d), jnp.float32)
    dv0 = jnp.zeros((b, sk, hkv, d), jnp.float32)
    (dk, dv), dq_blocks = jax.lax.scan(
        q_body, (dk0, dv0),
        (jnp.arange(nq), qb_all, dob_all, lse_b, del_b))
    dq = jnp.moveaxis(dq_blocks, 0, 1).reshape(b, sq, h, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_custom.defvjp(_flash_custom_fwd, _flash_custom_bwd)


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block, q_offset):
    """Returns (out (B,Sq,H,D), lse (B,Sq,H) f32)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv

    nq = sq // q_block
    qg = _split_heads(q, hkv).reshape(b, nq, q_block, hkv, g, d)
    qg = jnp.moveaxis(qg, 1, 0)                                 # (nq,B,Bq,Hkv,G,D)
    kpos_all = jnp.arange(sk)

    if window is not None:
        # banded path: fixed-width KV span per query block
        span = ((window + q_block + kv_block - 1) // kv_block) * kv_block
        span = min(span, sk)

        def q_body(_, inputs):
            qi, qblk = inputs
            qpos = q_offset + qi * q_block + jnp.arange(q_block)
            start = jnp.clip(q_offset + (qi + 1) * q_block - span, 0, sk - span)
            kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpos = start + jnp.arange(span)
            s = _attn_block(qblk, kb, vb, qpos, kpos, causal, window)
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m)
            l = jnp.sum(p, axis=-1, keepdims=True)
            o = jnp.einsum("bkgqs,bskd->bqkgd", p / jnp.maximum(l, 1e-30),
                           vb.astype(jnp.float32))
            lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]   # (B,Hkv,G,Bq)
            lse = lse.transpose(0, 3, 1, 2).reshape(b, q_block, h)
            return None, (o.reshape(b, q_block, h, d), lse)

        _, (blocks, lses) = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
        out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, d)
        lse = jnp.moveaxis(lses, 0, 1).reshape(b, sq, h)
        return out.astype(q.dtype), lse

    # full (causal or bidirectional) path: online softmax over all kv blocks
    nk = sk // kv_block
    kb_all = k.reshape(b, nk, kv_block, hkv, d)
    vb_all = v.reshape(b, nk, kv_block, hkv, d)

    def q_body(_, inputs):
        qi, qblk = inputs
        qpos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_body(carry, kv_in):
            o_acc, m_acc, l_acc = carry
            ki, kb, vb = kv_in
            kpos = ki * kv_block + jnp.arange(kv_block)
            s = _attn_block(qblk, kb, vb, qpos, kpos, causal, None)
            m_new = jnp.maximum(m_acc, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            scale = jnp.exp(m_acc - m_new)
            l_new = l_acc * scale + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32))
            o_new = o_acc * scale[..., 0][..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, q_block, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block, 1), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_body, (o0, m0, l0),
            (jnp.arange(nk), jnp.moveaxis(kb_all, 1, 0), jnp.moveaxis(vb_all, 1, 0)),
        )
        o = o / jnp.maximum(l[..., 0][..., None], 1e-30)
        o = jnp.moveaxis(o, 3, 1).reshape(b, q_block, h, d)    # (B,Bq,H,D)
        lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]     # (B,Hkv,G,Bq)
        lse = lse.transpose(0, 3, 1, 2).reshape(b, q_block, h)
        return None, (o, lse)

    _, (blocks, lses) = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, d)
    lse = jnp.moveaxis(lses, 0, 1).reshape(b, sq, h)
    return out.astype(q.dtype), lse


def decode_attention(q1, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     ring: bool = False,
                     start=None) -> jnp.ndarray:
    """One-token decode: q1 (B, 1, H, D) vs cache (B, Sc, Hkv, D).

    cache_len: number of valid cached tokens (new token already written).
    ring=True: the cache is a ring buffer of size `window`; slot i holds
    absolute position p where p % window == i.
    start: optional (B,) per-lane first valid absolute position — cache
    entries before it are masked out.  This is the stale-KV mask for
    continuous-batching engines that reuse a batch lane for a new request
    (`repro.launch.serve.Engine`): lane b's previous occupant wrote
    positions < start[b], which must not leak into the new stream.
    """
    b, _, h, d = q1.shape
    _, sc, hkv, _ = k_cache.shape
    qg = _split_heads(q1, hkv)[:, 0]                          # (B,Hkv,G,D)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / jnp.sqrt(d)
    slot = jnp.arange(sc)
    if ring:
        assert window is not None and sc == window
        # absolute position of each slot given cache_len tokens seen
        cur = cache_len - 1                                   # newest position
        pos = slot + (jnp.ceil((cur + 1 - slot) / sc)).astype(slot.dtype) * sc - sc
        valid = (pos >= 0) & (pos >= cache_len - window) & (pos <= cur)
    else:
        pos = slot                       # non-ring: slot == absolute position
        valid = slot < cache_len
        if window is not None:
            valid &= slot >= cache_len - window
    if start is not None:
        # per-lane mask (B, Sc): a slot whose (attributed) absolute position
        # precedes the lane's stream start was written by a previous
        # occupant; masking by position also covers the ring case, where a
        # stale slot is attributed the newest position that maps to it
        valid = valid[None, :] & (pos[None, :] >= jnp.reshape(start, (-1, 1)))
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    else:
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q1.dtype)
