from repro.nn import layers  # noqa: F401
