"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

Both use stabilized exponential gating.  The mLSTM keeps a per-head matrix
memory C (dh, dh) + normalizer n (dh,); the sLSTM keeps scalar memories
with block-diagonal (per-head) recurrence.  Sequence mixing is a
``lax.scan``; decoding carries the recurrent state explicitly so one token
is O(dh^2) (mLSTM) / O(d) (sLSTM) — this is what makes the 500k-token
decode shape feasible for this family.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import layers as L


def _chunked_scan(cell, state, seqs, s: int, chunk: int = 64):
    """Two-level scan: backward saves the carry only at chunk boundaries
    (the inner body is rematerialized), turning the O(S) saved matrix
    memories of the recurrent cells into O(S/chunk + chunk)."""
    if chunk > 1 and s % chunk == 0 and s > chunk:
        nc = s // chunk

        @jax.checkpoint
        def chunk_body(carry, ch):
            return jax.lax.scan(cell, carry, ch)

        chunked = tuple(t.reshape(nc, chunk, *t.shape[1:]) for t in seqs)
        state, ys = jax.lax.scan(chunk_body, state, chunked)
        return state, ys.reshape(s, *ys.shape[2:])
    return jax.lax.scan(cell, state, seqs)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(rng, d_model: int, n_heads: int, dtype=jnp.float32):
    dh = d_model // n_heads
    r = jax.random.split(rng, 6)
    s = (1.0 / d_model) ** 0.5
    return {
        "wqkv": (jax.random.normal(r[0], (d_model, 3 * d_model), jnp.float32) * s
                 ).astype(dtype),
        "wif": (jax.random.normal(r[1], (d_model, 2 * n_heads), jnp.float32) * s
                ).astype(dtype),
        "b_i": jnp.zeros((n_heads,), dtype),
        "b_f": jnp.full((n_heads,), 3.0, dtype),          # forget-gate bias
        "wo": (jax.random.normal(r[2], (d_model, d_model), jnp.float32) * s
               ).astype(dtype),
        "gn": L.rmsnorm_init(d_model, dtype),
        "wz": (jax.random.normal(r[3], (d_model, d_model), jnp.float32) * s
               ).astype(dtype),
    }


def _mlstm_cell(carry, inp):
    """carry: (C (B,H,dh,dh), n (B,H,dh), m (B,H)); inp: q,k,v,(B,H,dh), i,f raw (B,H)."""
    c, n, m = carry
    q, k, v, i_raw, f_raw = inp
    logf = jax.nn.log_sigmoid(f_raw)                      # (B,H)
    m_new = jnp.maximum(logf + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)[..., None]               # (B,H,1)
    f_g = jnp.exp(logf + m - m_new)[..., None]
    c = f_g[..., None] * c + i_g[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_g * n + i_g * k
    num = jnp.einsum("bhde,bhe->bhd", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)[..., None]
    h = num / den
    return (c, n, m_new), h


def mlstm_apply(params, x: jnp.ndarray, n_heads: int,
                state: Optional[Tuple] = None, chunkwise: bool = True,
                chunk: int = 64):
    """(B, S, D) -> (B, S, D), final state.  state carries (C, n, m).

    chunkwise=True uses the parallel chunk form (matmul-dominant; the
    (dh, dh) matrix memory only materializes at chunk boundaries —
    see mlstm_chunkwise).  The stepwise scan remains for decode and as
    the numerical reference."""
    b, s, d = x.shape
    dh = d // n_heads
    qkv = x @ params["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    scale = 1.0 / (dh ** 0.5)
    q = q.reshape(b, s, n_heads, dh).astype(jnp.float32)
    k = (k.reshape(b, s, n_heads, dh) * scale).astype(jnp.float32)
    v = v.reshape(b, s, n_heads, dh).astype(jnp.float32)
    gi = (x @ params["wif"]).astype(jnp.float32)
    i_raw = gi[..., :n_heads] + params["b_i"]
    f_raw = gi[..., n_heads:] + params["b_f"]

    if state is None:
        state = (jnp.zeros((b, n_heads, dh, dh), jnp.float32),
                 jnp.zeros((b, n_heads, dh), jnp.float32),
                 jnp.full((b, n_heads), -1e30, jnp.float32))
    if chunkwise and s % chunk == 0 and s >= chunk:
        state, h = mlstm_chunkwise(q, k, v, i_raw, f_raw, state, chunk)
        h = h.reshape(b, s, d).astype(x.dtype)
    else:
        mv = lambda a: jnp.moveaxis(a, 1, 0)
        state, hs = _chunked_scan(_mlstm_cell, state,
                                  (mv(q), mv(k), mv(v), mv(i_raw), mv(f_raw)), s)
        h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    h = L.rmsnorm_apply(params["gn"], h)
    h = h * jax.nn.silu(x @ params["wz"])                 # output gate branch
    return h @ params["wo"], state


def mlstm_chunkwise(q, k, v, i_raw, f_raw, state, chunk: int):
    """Chunkwise-parallel mLSTM (xLSTM appendix form, TPU-adapted).

    Within a chunk the output is an attention-like masked product
    (intra term, an (L, L) matmul on the MXU) plus the carried matrix
    memory applied once (inter term); the (dh, dh) state is updated once
    per chunk.  HBM traffic for the state drops from O(S * dh^2) to
    O(S/L * dh^2) — the §Perf xlstm hillclimb (EXPERIMENTS.md).

    q, k, v: (B, S, H, dh) f32 (k pre-scaled); i_raw, f_raw: (B, S, H).
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)).  Stored state carries the
    exp(-m) stabilizer, matching `_mlstm_cell` bit-for-bit semantics.
    """
    b, s, h, dh = q.shape
    nc = s // chunk
    neg = -1e30

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)   # (nc,B,L,H,dh)
    ic, fc = to_chunks(i_raw), to_chunks(f_raw)             # (nc,B,L,H)

    def chunk_body(carry, inp):
        c0, n0, m0 = carry                                  # (B,H,dh,dh) ...
        qq, kk, vv, ii, ff = inp                            # (B,L,H,*)
        logf = jax.nn.log_sigmoid(ff)                       # (B,L,H)
        bcum = jnp.cumsum(logf, axis=1)                     # b_t, t=1..L
        # intra log-weights a[t,s] = b_t - b_s + i_s  (s <= t)
        a = (bcum[:, :, None] - bcum[:, None, :]
             + ii[:, None, :, :])                           # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        a = jnp.where(tri[None, :, :, None], a, neg)
        g = bcum + m0[:, None]                              # (B,L,H) carry weight
        m_t = jnp.maximum(g, jnp.max(a, axis=2))            # (B,L,H)
        w = jnp.exp(a - m_t[:, :, None])                    # (B,t,s,H)
        cw = jnp.exp(g - m_t)                               # (B,L,H)

        scores = jnp.einsum("blhd,bshd->blsh", qq, kk)      # (B,t,s,H)
        wsc = w * scores
        num = (jnp.einsum("blsh,bshd->blhd", wsc, vv)
               + cw[..., None] * jnp.einsum("bhde,blhe->blhd", c0, qq))
        den = (jnp.sum(wsc, axis=2)
               + cw * jnp.einsum("bhd,blhd->blh", n0, qq))
        hh = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]  # (B,L,H,dh)

        # ---- state update (once per chunk) ----
        m_l = m_t[:, -1]                                    # (B,H)
        wl = jnp.exp(bcum[:, -1:, :] - bcum + ii - m_l[:, None])  # (B,s,H)
        c_new = (jnp.exp(bcum[:, -1] + m0 - m_l)[..., None, None] * c0
                 + jnp.einsum("bshd,bsh,bshe->bhde", vv, wl, kk))
        n_new = (jnp.exp(bcum[:, -1] + m0 - m_l)[..., None] * n0
                 + jnp.einsum("bsh,bshd->bhd", wl, kk))
        return (c_new, n_new, m_l), hh

    state, hs = jax.lax.scan(chunk_body, state, (qc, kc, vc, ic, fc))
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, s, h, dh)
    return state, hout


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(rng, d_model: int, n_heads: int, dtype=jnp.float32):
    r = jax.random.split(rng, 3)
    s = (1.0 / d_model) ** 0.5
    dh = d_model // n_heads
    return {
        "wx": (jax.random.normal(r[0], (d_model, 4 * d_model), jnp.float32) * s
               ).astype(dtype),
        # block-diagonal recurrence: per-head (dh, 4*dh)
        "rh": (jax.random.normal(r[1], (n_heads, dh, 4 * dh), jnp.float32)
               * (1.0 / dh) ** 0.5).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d_model,), jnp.float32),
                              jnp.full((d_model,), 3.0, jnp.float32),
                              jnp.zeros((d_model,), jnp.float32)]).astype(dtype),
        "gn": L.rmsnorm_init(d_model, dtype),
        "wo": (jax.random.normal(r[2], (d_model, d_model), jnp.float32) * s
               ).astype(dtype),
    }


def slstm_apply(params, x: jnp.ndarray, n_heads: int,
                state: Optional[Tuple] = None):
    """(B, S, D) -> (B, S, D), final state (c, n, m, h)."""
    b, s, d = x.shape
    dh = d // n_heads
    wx = (x @ params["wx"]).astype(jnp.float32)           # (B,S,4D)

    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = (z, z + 1e-6, jnp.full((b, d), -1e30, jnp.float32), z)

    rh = params["rh"].astype(jnp.float32)
    bias = params["b"].astype(jnp.float32)

    def cell(carry, inp):
        (wx_t,) = inp
        c, n, m, h_prev = carry
        hh = h_prev.reshape(b, n_heads, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, rh).reshape(b, 4 * d)
        pre = wx_t + rec + bias
        z_t = jnp.tanh(pre[:, :d])
        i_raw = pre[:, d : 2 * d]
        f_raw = pre[:, 2 * d : 3 * d]
        o_t = jax.nn.sigmoid(pre[:, 3 * d :])
        logf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(logf + m, i_raw)
        i_g = jnp.exp(i_raw - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c = f_g * c + i_g * z_t
        n = f_g * n + i_g
        h = o_t * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    state, hs = _chunked_scan(cell, state, (jnp.moveaxis(wx, 1, 0),), s)
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = L.rmsnorm_apply(params["gn"], h)
    return h @ params["wo"], state
