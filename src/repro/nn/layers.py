"""Minimal pure-JAX module substrate (no flax).

Params are explicit pytrees (nested dicts of jnp arrays).  Every layer is a
pair of functions: ``init(rng, ...) -> params`` and ``apply(params, x, ...)``.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------
def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32, scale: Optional[float] = None):
    w_rng, _ = jax.random.split(rng)
    s = scale if scale is not None else (2.0 / in_dim) ** 0.5  # He init (ReLU nets)
    return {
        "w": (jax.random.normal(w_rng, (in_dim, out_dim), jnp.float32) * s).astype(dtype),
        "b": jnp.zeros((out_dim,), dtype),
    }


def dense_apply(params, x):
    return x @ params["w"] + params["b"]


def mlp_init(rng, in_dim: int, hidden: Sequence[int], out_dim: int, dtype=jnp.float32):
    dims = [in_dim, *hidden, out_dim]
    rngs = jax.random.split(rng, len(dims) - 1)
    layers = []
    for i, r in enumerate(rngs):
        last = i == len(dims) - 2
        scale = (1.0 / dims[i]) ** 0.5 if last else None
        layers.append(dense_init(r, dims[i], dims[i + 1], dtype, scale=scale))
    return {"layers": layers}


def mlp_apply(params, x, activation=jax.nn.relu,
              use_fused: Optional[bool] = None, interpret: bool = False):
    """Plain MLP: hidden layers with `activation`, linear final layer.

    ``use_fused`` routes every layer through the Pallas fused
    dense+bias+ReLU kernels (kernels/fused_mlp.py, differentiable via
    their custom_vjp): ``None`` = backend auto (TPU on, CPU/GPU off —
    see kernels/dispatch.py), ``True``/``False`` force it.  The fused
    kernels hard-wire ReLU, so a non-ReLU ``activation`` raises when
    fusion was explicitly requested and silently takes the unfused path
    on auto (it is never ignored).
    """
    layers = params["layers"]
    from repro.kernels import dispatch as D
    if activation is not jax.nn.relu:
        if use_fused:
            raise ValueError(
                "mlp_apply(use_fused=True) supports only jax.nn.relu — the "
                f"fused kernel hard-wires the ReLU epilogue; got {activation!r}. "
                "Pass use_fused=None/False to use the unfused path.")
        # non-ReLU: always the unfused path, interpret included — there is
        # no kernel for this activation, so it is honored, never replaced
    elif D.kernel_route_active(use_fused, interpret):
        for p in layers[:-1]:
            x = D.dense(x, p["w"], p["b"], relu=True, use_fused=use_fused,
                        interpret=interpret)
        return D.dense(x, layers[-1]["w"], layers[-1]["b"], relu=False,
                       use_fused=use_fused, interpret=interpret)
    for p in layers[:-1]:
        x = activation(dense_apply(p, x))
    return dense_apply(layers[-1], x)


def mlp_apply_chained(params, x, use_fused: Optional[bool] = None,
                      interpret: bool = False):
    """Inference-only MLP forward (hidden ReLU, linear head) through the
    layer-chained megakernel on the fused route: activations stay in VMEM
    across layers instead of one HBM round-trip per layer.  Differentiable
    too (the megakernel's VJP re-runs the fused_dense chain), but training
    should prefer ``mlp_apply`` — its per-layer backward is cheaper."""
    from repro.kernels import dispatch as D
    return D.mlp_chain(params["layers"], x, use_fused=use_fused,
                       interpret=interpret)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)}


def embed_apply(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def embed_logits(params, x):
    """Tied-embedding output head."""
    return x @ params["table"].T


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                     # (half,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, half)
    ang = ang[..., None, :]                                # (..., seq, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections: Tuple[int, int, int], theta: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL): rotary dims are partitioned into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (..., seq, heads, head_dim); positions_3d: (3, ..., seq).
    sections: half-dim split per modality axis, sum == head_dim // 2.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)                     # (half,)
    # angles per modality axis, then stitch the sections together
    angs = []
    off = 0
    for axis, sec in enumerate(sections):
        p = positions_3d[axis]
        a = p[..., :, None].astype(jnp.float32) * inv[off : off + sec]
        angs.append(a)
        off += sec
    ang = jnp.concatenate(angs, axis=-1)[..., None, :]     # (..., seq, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
