"""Composable model blocks: unified decoder block (GQA/SWA/qk-norm,
SwiGLU-FFN or MoE), whisper encoder/decoder blocks, hymba hybrid block.

All blocks are (init, apply) function pairs over explicit param pytrees so
layers can be stacked with ``jax.lax.scan`` (homogeneous params) by the
model builders.  Attention runs through the blocked-XLA flash path by
default and through the Pallas kernel on TPU (see kernels/ops.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as M
from repro.nn import ssm as S


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """Static per-architecture block hyperparameters."""

    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    qk_norm: bool = False
    window: Optional[int] = None        # sliding-window width (None = full)
    rope_theta: float = 10000.0
    n_experts: int = 0                  # 0 -> dense FFN
    top_k: int = 2
    ssm_state: int = 0                  # >0 -> hymba parallel SSM branch
    mrope_sections: Optional[Tuple[int, int, int]] = None

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# attention sub-layer
# ---------------------------------------------------------------------------
def attn_init(rng, cfg: BlockCfg, dtype=jnp.float32):
    dh = cfg.dh
    r = jax.random.split(rng, 4)
    s = (1.0 / cfg.d_model) ** 0.5
    p = {
        "wq": (jax.random.normal(r[0], (cfg.d_model, cfg.n_heads * dh), jnp.float32)
               * s).astype(dtype),
        "wkv": (jax.random.normal(r[1], (cfg.d_model, 2 * cfg.n_kv * dh), jnp.float32)
                * s).astype(dtype),
        "wo": (jax.random.normal(r[2], (cfg.n_heads * dh, cfg.d_model), jnp.float32)
               * (1.0 / (cfg.n_heads * dh)) ** 0.5).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh, dtype)
        p["k_norm"] = L.rmsnorm_init(dh, dtype)
    return p


def _qkv(params, x, cfg: BlockCfg, positions):
    b, s, _ = x.shape
    dh = cfg.dh
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, dh)
    kv = (x @ params["wkv"]).reshape(b, s, 2 * cfg.n_kv, dh)
    k, v = kv[:, :, : cfg.n_kv], kv[:, :, cfg.n_kv :]
    if cfg.qk_norm:
        q = L.rmsnorm_apply(params["q_norm"], q)
        k = L.rmsnorm_apply(params["k_norm"], k)
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:        # text-only: t/h/w positions coincide
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = L.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(params, x, cfg: BlockCfg, positions, *, causal: bool = True):
    """Full-sequence attention: x (B, S, D) -> (B, S, D)."""
    q, k, v = _qkv(params, x, cfg, positions)
    o = A.flash_attention_xla(q, k, v, causal=causal, window=cfg.window)
    b, s, _, _ = q.shape
    return o.reshape(b, s, -1) @ params["wo"]


def attn_decode(params, x1, cfg: BlockCfg, pos, kv_cache, cache_len, *,
                ring: bool = False, start=None):
    """One-token decode.  kv_cache: (k (B,Sc,Hkv,dh), v); returns
    (y1, new_cache).  `pos` is the absolute position (B,1) or scalar;
    `start` is the optional (B,) per-lane stale-KV mask (see
    `decode_attention`)."""
    positions = jnp.reshape(pos, (1, 1)) if jnp.ndim(pos) == 0 else pos
    q, k, v = _qkv(params, x1, cfg, positions)
    kc, vc = kv_cache
    slot = (cache_len % kc.shape[1]) if ring else cache_len
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
    o = A.decode_attention(q, kc, vc, cache_len + 1, window=cfg.window,
                           ring=ring, start=start)
    y = o.reshape(x1.shape[0], 1, -1) @ params["wo"]
    return y, (kc, vc)


# ---------------------------------------------------------------------------
# FFN sub-layer (SwiGLU) or MoE
# ---------------------------------------------------------------------------
def ffn_init(rng, cfg: BlockCfg, dtype=jnp.float32):
    if cfg.n_experts:
        return M.moe_init(rng, cfg.n_experts, cfg.d_model, cfg.d_ff, dtype)
    r = jax.random.split(rng, 3)
    s_in = (2.0 / cfg.d_model) ** 0.5
    return {
        "w_gate": (jax.random.normal(r[0], (cfg.d_model, cfg.d_ff), jnp.float32)
                   * s_in).astype(dtype),
        "w_up": (jax.random.normal(r[1], (cfg.d_model, cfg.d_ff), jnp.float32)
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(r[2], (cfg.d_ff, cfg.d_model), jnp.float32)
                   * (1.0 / cfg.d_ff) ** 0.5).astype(dtype),
    }


def ffn_apply(params, x, cfg: BlockCfg):
    if cfg.n_experts:
        b, s, d = x.shape
        y = M.moe_apply(params, x.reshape(b * s, d), top_k=cfg.top_k)
        return y.reshape(b, s, d)
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# unified decoder block
# ---------------------------------------------------------------------------
def block_init(rng, cfg: BlockCfg, dtype=jnp.float32):
    r = jax.random.split(rng, 3)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(r[0], cfg, dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
        "ffn": ffn_init(r[1], cfg, dtype),
    }
    if cfg.ssm_state:                   # hymba: parallel SSM branch
        p["ssm"] = S.ssm_init(r[2], cfg.d_model, cfg.ssm_state, dtype=dtype)
        p["mix_a"] = jnp.ones((), dtype)
        p["mix_s"] = jnp.ones((), dtype)
    return p


def block_apply(params, x, cfg: BlockCfg, positions):
    h = L.rmsnorm_apply(params["ln1"], x)
    mix = attn_apply(params["attn"], h, cfg, positions)
    if cfg.ssm_state:
        sm = S.ssm_apply(params["ssm"], h)
        mix = params["mix_a"] * mix + params["mix_s"] * sm
    x = x + mix
    h = L.rmsnorm_apply(params["ln2"], x)
    return x + ffn_apply(params["ffn"], h, cfg)


def block_decode(params, x1, cfg: BlockCfg, pos, state, *, ring: bool = False,
                 start=None):
    """state: {'kv': (k, v), 'len': int scalar, 'ssm': optional}."""
    h = L.rmsnorm_apply(params["ln1"], x1)
    mix, kv = attn_decode(params["attn"], h, cfg, pos, state["kv"],
                          state["len"], ring=ring, start=start)
    new_state = dict(state, kv=kv, len=state["len"] + 1)
    if cfg.ssm_state:
        sm, sst = S.ssm_decode_step(params["ssm"], h, state["ssm"])
        mix = params["mix_a"] * mix + params["mix_s"] * sm
        new_state["ssm"] = sst
    x1 = x1 + mix
    h = L.rmsnorm_apply(params["ln2"], x1)
    return x1 + ffn_apply(params["ffn"], h, cfg), new_state


# ---------------------------------------------------------------------------
# whisper-style encoder / decoder blocks (pre-LN, GELU MLP, abs pos handled
# by the model; encoder attention is bidirectional, decoder adds cross-attn)
# ---------------------------------------------------------------------------
def enc_block_init(rng, cfg: BlockCfg, dtype=jnp.float32):
    r = jax.random.split(rng, 2)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "attn": attn_init(r[0], cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "ffn": ffn_init(r[1], cfg, dtype),
    }


def enc_block_apply(params, x, cfg: BlockCfg, positions):
    h = L.layernorm_apply(params["ln1"], x)
    x = x + attn_apply(params["attn"], h, cfg, positions, causal=False)
    h = L.layernorm_apply(params["ln2"], x)
    g = jax.nn.gelu(h @ params["ffn"]["w_gate"])
    return x + (g * (h @ params["ffn"]["w_up"])) @ params["ffn"]["w_down"]


def dec_block_init(rng, cfg: BlockCfg, dtype=jnp.float32):
    r = jax.random.split(rng, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model, dtype),
        "self_attn": attn_init(r[0], cfg, dtype),
        "ln_x": L.layernorm_init(cfg.d_model, dtype),
        "cross_attn": attn_init(r[1], cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model, dtype),
        "ffn": ffn_init(r[2], cfg, dtype),
    }


def _cross_attn(params, x, enc_out, cfg: BlockCfg):
    b, s, _ = x.shape
    dh = cfg.dh
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, dh)
    se = enc_out.shape[1]
    kv = (enc_out @ params["wkv"]).reshape(b, se, 2 * cfg.n_kv, dh)
    k, v = kv[:, :, : cfg.n_kv], kv[:, :, cfg.n_kv :]
    o = A.attention_reference(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ params["wo"]


def dec_block_apply(params, x, enc_out, cfg: BlockCfg, positions):
    h = L.layernorm_apply(params["ln1"], x)
    x = x + attn_apply(params["self_attn"], h, cfg, positions, causal=True)
    h = L.layernorm_apply(params["ln_x"], x)
    x = x + _cross_attn(params["cross_attn"], h, enc_out, cfg)
    h = L.layernorm_apply(params["ln2"], x)
    g = jax.nn.gelu(h @ params["ffn"]["w_gate"])
    return x + (g * (h @ params["ffn"]["w_up"])) @ params["ffn"]["w_down"]


def dec_block_decode(params, x1, enc_out, cfg: BlockCfg, pos, state,
                     start=None):
    h = L.layernorm_apply(params["ln1"], x1)
    mix, kv = attn_decode(params["self_attn"], h, cfg, pos, state["kv"],
                          state["len"], start=start)
    x1 = x1 + mix
    h = L.layernorm_apply(params["ln_x"], x1)
    x1 = x1 + _cross_attn(params["cross_attn"], h, enc_out, cfg)
    h = L.layernorm_apply(params["ln2"], x1)
    g = jax.nn.gelu(h @ params["ffn"]["w_gate"])
    y = x1 + (g * (h @ params["ffn"]["w_up"])) @ params["ffn"]["w_down"]
    return y, dict(state, kv=kv, len=state["len"] + 1)
