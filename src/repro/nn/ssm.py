"""Selective state-space (Mamba-style) mixer — used by the hymba hybrid.

x (B, S, D) -> y (B, S, D) with per-channel selective SSM state of size N.
The recurrence is a ``jax.lax.scan`` over the sequence (one while-loop in
HLO regardless of S); decoding keeps an explicit (B, D, N) state and a
(B, K-1, D) conv tail so one token costs O(D*N).

Hardware note: the scan keeps the (B, D, N) state resident; on TPU the
per-step work is elementwise VPU work plus a (D, N) contraction — the
design follows the paper's *insight* (input-dependent gating of a linear
state) rather than the CUDA kernel structure of the original Mamba.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssm_init(rng, d_model: int, d_state: int = 16, d_conv: int = 4,
             expand: int = 2, dtype=jnp.float32):
    d_inner = expand * d_model
    r = jax.random.split(rng, 6)
    s = (2.0 / d_model) ** 0.5
    return {
        "in_proj": (jax.random.normal(r[0], (d_model, 2 * d_inner), jnp.float32) * s
                    ).astype(dtype),
        "conv_w": (jax.random.normal(r[1], (d_conv, d_inner), jnp.float32) * 0.2
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        # x -> (dt, B, C) projections
        "x_proj": (jax.random.normal(r[2], (d_inner, 1 + 2 * d_state), jnp.float32)
                   * (1.0 / d_inner) ** 0.5).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),     # softplus^-1(0.01)
        "dt_w": (jax.random.normal(r[3], (1, d_inner), jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_inner, 1))).astype(dtype),
        "D_skip": jnp.ones((d_inner,), dtype),
        "out_proj": (jax.random.normal(r[4], (d_inner, d_model), jnp.float32)
                     * (1.0 / d_inner) ** 0.5).astype(dtype),
    }


def _conv_causal(x, w, b, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq. x (B,S,Di), w (K,Di).

    tail: (B, K-1, Di) previous inputs for decode continuation."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+K-1, Di)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    return out + b[None, None]


def ssm_scan(params, xz: jnp.ndarray, h0: Optional[jnp.ndarray] = None,
             chunk: int = 64):
    """Core selective scan.  xz (B, S, 2*Di) from in_proj; returns
    (y (B,S,Di-projected..), h_final (B, Di, N)).

    Two-level chunked scan: the (B, S, Di, N) transition tensors are never
    materialized over the full sequence (only per chunk, inside a
    ``jax.checkpoint``-ed body), and backward saves the (B, Di, N) state
    only at chunk boundaries — O(S/chunk + chunk) memory instead of O(S).
    """
    d_inner = params["conv_w"].shape[1]
    d_state = (params["x_proj"].shape[1] - 1) // 2
    x, z = jnp.split(xz, 2, axis=-1)                      # (B,S,Di) each
    x = jax.nn.silu(_conv_causal(x, params["conv_w"], params["conv_b"]))

    proj = x @ params["x_proj"]                           # (B,S,1+2N)
    dt = jax.nn.softplus(proj[..., :1] @ params["dt_w"] + params["dt_bias"])
    bmat = proj[..., 1 : 1 + d_state]                     # (B,S,N)
    cmat = proj[..., 1 + d_state :]                       # (B,S,N)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))     # (Di,N)

    b, s, _ = x.shape
    h = jnp.zeros((b, d_inner, d_state), jnp.float32) if h0 is None else h0

    def step(hc, inp):
        dt_t, b_t, c_t, x_t = inp                         # (B,Di),(B,N),(B,N),(B,Di)
        da_t = jnp.exp(dt_t[..., None] * a[None])         # (B,Di,N)
        dbx_t = dt_t[..., None] * b_t[:, None] * x_t[..., None]
        hc = da_t * hc + dbx_t
        y = jnp.einsum("bdn,bn->bd", hc, c_t)
        return hc, y

    mv = lambda t: jnp.moveaxis(t, 1, 0)                  # (S,B,...)
    seqs = (mv(dt), mv(bmat), mv(cmat), mv(x))

    if chunk > 1 and s % chunk == 0 and s > chunk:
        nc = s // chunk

        @jax.checkpoint
        def chunk_body(hc, ch):
            return jax.lax.scan(step, hc, ch)

        chunked = tuple(t.reshape(nc, chunk, *t.shape[1:]) for t in seqs)
        h, ys = jax.lax.scan(chunk_body, h, chunked)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        h, ys = jax.lax.scan(step, h, seqs)

    y = jnp.moveaxis(ys, 0, 1) + x * params["D_skip"][None, None]
    y = y * jax.nn.silu(z)
    return y.astype(xz.dtype), h


def ssm_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence mixer: (B, S, D) -> (B, S, D)."""
    xz = x @ params["in_proj"]
    y, _ = ssm_scan(params, xz)
    return y @ params["out_proj"]


def ssm_decode_init(params, batch: int):
    """Empty decode state: (h, conv_tail)."""
    d_inner = params["conv_w"].shape[1]
    d_state = (params["x_proj"].shape[1] - 1) // 2
    k = params["conv_w"].shape[0]
    return (jnp.zeros((batch, d_inner, d_state), jnp.float32),
            jnp.zeros((batch, k - 1, d_inner), jnp.float32))


def ssm_decode_step(params, x1: jnp.ndarray, state):
    """One-token decode: x1 (B, 1, D) -> (y1 (B, 1, D), new state)."""
    h, tail = state
    xz = x1 @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                      # (B,1,Di)
    xc = jax.nn.silu(_conv_causal(x, params["conv_w"], params["conv_b"], tail=tail))
    new_tail = jnp.concatenate([tail[:, 1:], x.astype(tail.dtype)], axis=1)

    proj = xc @ params["x_proj"]
    d_state = (params["x_proj"].shape[1] - 1) // 2
    dt = jax.nn.softplus(proj[..., :1] @ params["dt_w"] + params["dt_bias"])
    bmat = proj[..., 1 : 1 + d_state]
    cmat = proj[..., 1 + d_state :]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0, :, None] * a[None])             # (B,Di,N)
    dbx = dt[:, 0, :, None] * bmat[:, 0, None] * xc[:, 0, :, None]
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
    y = y + xc * params["D_skip"][None, None]
    y = y * jax.nn.silu(z)
    return (y @ params["out_proj"]).astype(x1.dtype), (h, new_tail)
