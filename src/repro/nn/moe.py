"""Mixture-of-Experts layer (Mixtral / Phi-3.5-MoE style).

Top-k routing with **group-local capacity dispatch** (the GShard /
MaxText pattern adapted to pjit): tokens are split into G groups aligned
with the ('pod','data') mesh axes, each group routes its own tokens into
per-expert capacity buffers with scatter/gather (never an O(T x E x cap)
one-hot), and the expert SwiGLU FFNs run as one batched einsum over the
(group, expert) axes.  Because routing, scatter, and gather all stay
within a group, pjit partitions them on the group axis with no global
all-gather of the token stream — the dispatch collective reduces to the
expert einsums' usual TP all-reduces.

Tokens overflowing an expert's *per-group* capacity are dropped (standard
GShard behaviour — group-local capacity also matches how Mixtral-style
deployments bound the all-to-all); the router runs in float32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import layers as L


def moe_init(rng, n_experts: int, d_model: int, d_ff: int, dtype=jnp.float32):
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (1.0 / d_ff) ** 0.5
    return {
        "router": (jax.random.normal(r1, (d_model, n_experts), jnp.float32) * 0.02
                   ).astype(dtype),
        "w_gate": (jax.random.normal(r2, (n_experts, d_model, d_ff), jnp.float32)
                   * s_in).astype(dtype),
        "w_up": (jax.random.normal(r3, (n_experts, d_model, d_ff), jnp.float32)
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(r4, (n_experts, d_ff, d_model), jnp.float32)
                   * s_out).astype(dtype),
    }


def route_topk(router_logits: jnp.ndarray, top_k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., E) logits -> (..., K) expert indices and normalized weights."""
    w, idx = jax.lax.top_k(router_logits, top_k)
    w = jax.nn.softmax(w.astype(jnp.float32), axis=-1)
    return idx, w


def _dispatch_group(x, idx, wts, e: int, cap: int):
    """Group-local dispatch.  x (Tg, D); idx/wts (Tg, K).
    Returns (buf_tok (E*cap,), occupied (E*cap,), slot (Tg*K,), keep (Tg*K,))."""
    t, top_k = idx.shape
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)     # (Tg, K, E)
    flat = onehot.reshape(t * top_k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                  # slot within expert
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, top_k)
    keep = (pos < cap).reshape(t * top_k)
    pos_c = jnp.minimum(pos, cap - 1).astype(jnp.int32)
    slot = (idx.astype(jnp.int32) * cap + pos_c).reshape(t * top_k)
    token_of = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None],
                                (t, top_k)).reshape(t * top_k)
    slot_safe = jnp.where(keep, slot, e * cap)             # dropped -> overflow
    buf_tok = jnp.zeros((e * cap + 1,), jnp.int32).at[slot_safe].set(
        token_of, mode="drop")[:-1]
    occupied = jnp.zeros((e * cap + 1,), jnp.float32).at[slot_safe].set(
        keep.astype(jnp.float32), mode="drop")[:-1]
    return buf_tok, occupied, slot, keep


def _num_groups(t: int) -> int:
    """Groups = the ('pod','data') mesh extent when it divides T."""
    from repro.train import shardings as SH
    mesh = SH.current_mesh()
    if mesh is None:
        return 1
    g = SH.axis_size(mesh, SH.batch_axes(mesh))
    return g if g > 1 and t % g == 0 else 1


def moe_apply(
    params,
    x: jnp.ndarray,              # (T, Dm) flattened tokens
    *,
    top_k: int = 2,
    capacity_factor: float = 1.25,
    aux_loss: bool = False,
):
    """Returns (T, Dm) [and the load-balancing aux loss if requested]."""
    t, dm = x.shape
    e = params["router"].shape[-1]
    g = _num_groups(t)
    tg = t // g
    cap = max(int(capacity_factor * top_k * tg / e), 1)

    from repro.train import shardings as SH

    def _c(arr, *axes):
        mesh = SH.current_mesh()
        if mesh is None:
            return arr
        from jax.sharding import PartitionSpec as P
        spec = []
        for dim, ax in zip(arr.shape, axes):
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a in mesh.shape) or None
            elif ax is not None and ax not in mesh.shape:
                ax = None
            size = SH.axis_size(mesh, ax) if ax is not None else 1
            spec.append(ax if ax is not None and dim % size == 0 else None)
        return SH.constrain(arr, P(*spec))

    ba = ("pod", "data")
    # expert parallelism when E divides the 'model' axis (else TP on F)
    mesh = SH.current_mesh()
    e_par = (mesh is not None and "model" in mesh.shape
             and e % SH.axis_size(mesh, "model") == 0)
    e_ax = "model" if e_par else None
    f_ax = None if e_par else "model"
    xg = _c(x.reshape(g, tg, dm), ba, None, None)
    logits = (xg.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    idx, wts = route_topk(logits, top_k)                   # (G,Tg,K)

    buf_tok, occupied, slot, keep = jax.vmap(
        lambda xx, ii, ww: _dispatch_group(xx, ii, ww, e, cap))(xg, idx, wts)
    # buf_tok/occupied (G, E*cap); slot/keep (G, Tg*K)

    # expert compute in f32: a bf16 variant was tried (§Perf B iter. 5)
    # and REGRESSED the collective term 9% — XLA pairs the narrower
    # buffers with extra convert/reshard collectives; keep f32
    cdt = jnp.float32
    xe = jnp.take_along_axis(xg.astype(cdt),
                             buf_tok[..., None], axis=1)   # (G, E*cap, D)
    xe = (xe * occupied[..., None].astype(cdt)).reshape(g, e, cap, dm)
    xe = _c(xe, ba, e_ax, None, None)
    gg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                params["w_gate"].astype(cdt),
                                preferred_element_type=jnp.float32))
    uu = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(cdt),
                    preferred_element_type=jnp.float32)
    gg = _c(gg.astype(cdt), ba, e_ax, None, f_ax)
    uu = _c(uu.astype(cdt), ba, e_ax, None, f_ax)
    ye = jnp.einsum("gecf,efd->gecd", gg * uu,
                    params["w_down"].astype(cdt),
                    preferred_element_type=jnp.float32).astype(cdt)
    ye = _c(ye, ba, e_ax, None, None)

    # combine: each kept assignment needs its slot's output back
    w_keep = (wts.reshape(g, tg * top_k, 1)
              * keep[..., None].astype(jnp.float32))
    if e_par:
        # expert-parallel combine: gather WITHIN each expert's (local)
        # buffer by capacity position, select the owning expert with a
        # one-hot contraction over E — lowers to per-shard work plus one
        # all-reduce of the (G, TgK, D) outputs instead of an all-gather
        # of the (G, E, cap, D) buffers across the expert axis
        # (§Perf B iteration 4).
        pos_idx = jnp.minimum(slot % cap, cap - 1)          # (G, Tg*K)
        gathered = jnp.take_along_axis(
            ye, pos_idx[:, None, :, None], axis=2)          # (G, E, TgK, D)
        gathered = _c(gathered, ba, "model", None, None)
        own = jax.nn.one_hot(slot // cap, e, dtype=ye.dtype)  # (G,TgK,E)
        per_assign = jnp.einsum("getd,gte->gtd", gathered, own,
                                preferred_element_type=jnp.float32)
        per_assign = _c(per_assign, ba, None, None)
    else:
        per_assign = jnp.take_along_axis(
            ye.reshape(g, e * cap, dm),
            jnp.minimum(slot, e * cap - 1)[..., None], axis=1)  # (G, Tg*K, D)
        per_assign = _c(per_assign, ba, None, None)
    per_assign = per_assign * w_keep
    y = jnp.sum(per_assign.reshape(g, tg, top_k, dm), axis=2)
    y = y.reshape(t, dm).astype(x.dtype)

    if not aux_loss:
        return y
    # Switch-style load-balancing loss (over all tokens)
    onehot1 = jax.nn.one_hot(idx[..., 0].reshape(-1), e, dtype=jnp.float32)
    me = jnp.mean(onehot1, axis=0)
    pe = jnp.mean(jax.nn.softmax(logits.reshape(-1, e), -1), axis=0)
    return y, e * jnp.sum(me * pe)
