"""Fault-tolerant training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
      --max-restarts 3 [--simulate-failure-at 57]

Features exercised here (and tested in tests/test_traindriver.py):
  * checkpoint/restart: auto-resume from the latest valid checkpoint;
  * retry loop: an in-run failure (simulated preemption included) restarts
    the run up to --max-restarts times, resuming from the checkpoint;
  * deterministic data: the synthetic stream is keyed by step, so a
    restarted run replays exactly the batches it would have seen;
  * straggler watchdog: per-step wall time is tracked and steps slower
    than ``watchdog_factor x`` the running median are logged (on real
    multi-host deployments this feeds the controller's slow-host list).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import DataConfig, SyntheticStream
from repro.launch.mesh import make_host_mesh
from repro.models import base as MB
from repro.train import step as TS


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0):
        self.factor = factor
        self.times = []
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 8:
            return False
        med = float(np.median(self.times[-64:]))
        if dt > self.factor * med:
            self.flagged += 1
            return True
        return False


def run_once(args, start_step: int, params, opt_state, ckpt: CheckpointManager,
             stream: SyntheticStream, train_step, history: list) -> int:
    """Train from start_step; returns the step reached.  Raises to trigger
    the launcher's restart path."""
    watchdog = StragglerWatchdog()
    step = start_step
    while step < args.steps:
        toks, labels = stream.batch(step)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        t0 = time.time()
        if args.simulate_failure_at is not None and step == args.simulate_failure_at:
            args.simulate_failure_at = None       # fail only once
            raise RuntimeError("simulated node failure (preemption)")
        params, opt_state, metrics = train_step(params, opt_state, batch)
        dt = time.time() - t0
        slow = watchdog.record(dt)
        step += 1
        if step % args.log_every == 0 or step == args.steps:
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss, "dt": dt})
            print(f"[train] step={step} loss={loss:.4f} dt={dt*1e3:.0f}ms"
                  + (" STRAGGLER" if slow else ""), flush=True)
        if step % args.ckpt_every == 0 or step == args.steps:
            ckpt.save(step, {"params": params, "opt": opt_state},
                      extra={"step": step})
    return step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    m = configs.get_reduced(args.arch) if args.reduced else configs.get_arch(args.arch)
    mesh = make_host_mesh()
    train_step_fn, optim = TS.make_train_step(m, lr=args.lr, remat=False,
                                              mesh=mesh)
    train_step_fn = jax.jit(train_step_fn, donate_argnums=(0, 1))

    rng = jax.random.PRNGKey(args.seed)
    params = MB.init_params(rng, m)
    opt_state = optim.init(params)
    ckpt = CheckpointManager(args.ckpt_dir)
    stream = SyntheticStream(DataConfig(vocab=m.vocab, seq_len=args.seq,
                                        global_batch=args.batch,
                                        seed=args.seed))

    history: list = []
    restarts = 0
    while True:
        start = ckpt.latest_step() or 0
        if start:
            state = ckpt.restore(start, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[launcher] resumed from checkpoint step={start}", flush=True)
        try:
            step = run_once(args, start, params, opt_state, ckpt, stream,
                            train_step_fn, history)
            break
        except Exception as e:
            restarts += 1
            print(f"[launcher] run failed ({e}); restart {restarts}/"
                  f"{args.max_restarts}", flush=True)
            if restarts > args.max_restarts:
                raise
    print(f"[launcher] done at step={step} after {restarts} restart(s)")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
