import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory / cost / collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch mixtral-8x7b ...] [--shape train_4k ...] \
      [--mesh single|multi|both] [--out results/dryrun.jsonl]

Success criterion: ``jax.jit(step).lower(**input_specs).compile()``
succeeds for the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for
every applicable cell.  The compiled artifacts also feed §Roofline.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import base as MB
from repro.train import step as TS
from repro.utils import roofline as RL


def active_param_fraction_flops(m, p_struct) -> float:
    """Active (per-token) params: MoE expert tensors count top_k/E."""
    import jax.tree_util as jtu
    total = 0.0
    for path, leaf in jtu.tree_leaves_with_path(p_struct):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        n = float(np.prod(leaf.shape))
        if name in ("w_gate", "w_up", "w_down") and len(leaf.shape) >= 3:
            e = leaf.shape[-3]
            # find top_k from the arch (uniform across segments)
            top_k = 2
            for seg in m.segments:
                for spec in seg.pattern:
                    if spec.cfg.n_experts:
                        top_k = spec.cfg.top_k
            n *= top_k / e
        total += n
    # embedding lookup is not a matmul; subtract the embed table once
    embed = float(np.prod(p_struct["embed"]["table"].shape))
    return max(total - embed, 1.0)


def model_flops_for(m, shape, p_struct) -> float:
    n_active = active_param_fraction_flops(m, p_struct)
    if m.enc_segments is not None:
        # enc-dec: encoder params see seq_len frames, decoder params see
        # the decoder context
        n_enc = float(sum(np.prod(l.shape) for l in
                          jax.tree_util.tree_leaves(p_struct["encoder"])))
        n_dec = max(n_active - n_enc, 1.0)
        dec_toks = shape.global_batch * min(TS.WHISPER_DEC_LEN, shape.seq_len)
        enc_toks = shape.global_batch * shape.seq_len
        if shape.kind == "train":
            return (RL.model_flops_train(n_enc, enc_toks)
                    + RL.model_flops_train(n_dec, dec_toks))
        if shape.kind == "prefill":
            return (RL.model_flops_forward(n_enc, enc_toks)
                    + RL.model_flops_forward(n_dec, dec_toks))
        return RL.model_flops_forward(n_dec, shape.global_batch)
    if shape.kind == "train":
        return RL.model_flops_train(n_active, shape.global_batch * shape.seq_len)
    if shape.kind == "prefill":
        return RL.model_flops_forward(n_active, shape.global_batch * shape.seq_len)
    return RL.model_flops_forward(n_active, shape.global_batch)  # decode: 1 tok


# grad-accumulation defaults for the train_4k cells: chosen so the
# activation working set fits 16 GB/chip HBM (see EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES = {
    "mixtral-8x7b": 8, "phi3.5-moe-42b-a6.6b": 8, "deepseek-coder-33b": 4,
    "qwen3-14b": 2, "qwen2-vl-7b": 2, "gemma3-1b": 2,
    "xlstm-1.3b": 4, "hymba-1.5b": 8, "stablelm-1.6b": 1,
}


def run_cell(arch_name: str, shape_name: str, mesh, mesh_name: str,
             verbose: bool = True, microbatches: int = 0) -> dict:
    m = configs.get_arch(arch_name)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "chips": int(np.prod(list(mesh.shape.values())))}
    if not applicable(m, shape):
        rec["status"] = "skipped"
        rec["reason"] = m.notes
        return rec
    if not microbatches:
        microbatches = (TRAIN_MICROBATCHES.get(m.name, 1)
                        if shape.kind == "train" else 1)
    rec["microbatches"] = microbatches
    t0 = time.time()
    try:
        case = TS.build_case(m, shape, mesh, microbatches=microbatches)
        jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                         donate_argnums=case.donate_argnums)
        with mesh:
            lowered = jitted.lower(*case.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        chips = rec["chips"]
        rl = RL.from_compiled(case.name, compiled, hlo, chips,
                              model_flops=model_flops_for(m, shape,
                                                          case.args[0]))
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0)
                                 + getattr(mem, "argument_size_in_bytes", 0)
                                 + getattr(mem, "output_size_in_bytes", 0)
                                 - getattr(mem, "alias_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            flops=rl.flops, hbm_bytes=rl.hbm_bytes, coll_bytes=rl.coll_bytes,
            model_flops=rl.model_flops,
            **{k: v for k, v in rl.row().items() if k != "case"},
        )
        from repro.utils.hlo_cost import analyze
        t = analyze(hlo)
        rec["collectives"] = {k: v for k, v in t.items() if k.startswith("coll")}
        # raw XLA numbers for reference (loop bodies counted once)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_raw_flops"] = float(ca.get("flops", 0.0))
        rec["xla_raw_bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            traceback.print_exc()
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=configs.list_archs())
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--micro", type=int, default=0,
                    help="override grad-accum microbatches (train cells)")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for arch in args.arch:
            for shape in args.shape:
                for mesh_name, mesh in meshes:
                    rec = run_cell(arch, shape, mesh, mesh_name,
                                   microbatches=args.micro)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    status = rec["status"]
                    n_fail += status == "fail"
                    extra = (f" bottleneck={rec.get('bottleneck')}"
                             f" t_bound={max(rec.get('t_compute_s', 0) or 0, rec.get('t_memory_s', 0) or 0, rec.get('t_collective_s', 0) or 0):.4f}s"
                             if status == "ok" else rec.get("error", rec.get("reason", "")))
                    print(f"[dryrun] {arch:22s} {shape:12s} {mesh_name:18s} "
                          f"{status:7s}{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
