"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16);
multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16) where 'pod'
is the pure-DP cross-pod axis (DCN) and the inner axes are ICI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """`jax.make_mesh` with explicit Auto axis types where the installed
    jax supports them (>= 0.5); older versions have no AxisType and their
    meshes are implicitly Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic variant: any (pods, data, model) factorization whose product
    matches the available device count."""
    return _make_mesh(shape, axes)


def make_host_mesh(shape: Optional[Tuple[int, ...]] = None,
                   axes: Optional[Tuple[str, ...]] = None):
    """Whatever this host has (CPU smoke tests: 1 device).

    Default: all host devices as (data=n, model=1).  Pass ``shape``/``axes``
    to override the factorization — e.g. ``shape=(2, 2)`` to exercise a
    real 'model' axis on 4 fake CPU devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``), or
    ``shape=(2, 1)`` for a submesh over the first 2 of N devices (how
    ``bench_shard.py`` measures 1 -> N scaling in one process).  The shape
    product must not exceed the host device count.
    """
    import numpy as np

    devices = jax.devices()
    n = len(devices)
    if shape is None:
        assert axes is None, "axes override requires an explicit shape"
        return _make_mesh((n, 1), ("data", "model"))
    shape = tuple(int(s) for s in shape)
    if axes is None:
        axes = ("data", "model")[:len(shape)] if len(shape) <= 2 \
            else ("pod", "data", "model")[:len(shape)]
    if len(axes) != len(shape):
        raise ValueError(f"mesh shape {shape} has {len(shape)} dims but "
                         f"axes {axes} names {len(axes)}")
    want = int(np.prod(shape))
    if want > n:
        raise ValueError(
            f"mesh shape {shape} asks for {want} devices but this host has "
            f"only {n} (len(jax.devices())); reduce the shape or raise "
            f"--xla_force_host_platform_device_count")
    if want == n:
        return _make_mesh(shape, tuple(axes))
    # submesh over the first `want` devices (jax.make_mesh always takes all)
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:want]).reshape(shape), tuple(axes))
