"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16);
multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16) where 'pod'
is the pure-DP cross-pod axis (DCN) and the inner axes are ICI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """`jax.make_mesh` with explicit Auto axis types where the installed
    jax supports them (>= 0.5); older versions have no AxisType and their
    meshes are implicitly Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic variant: any (pods, data, model) factorization whose product
    matches the available device count."""
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))
