"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16);
multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16) where 'pod'
is the pure-DP cross-pod axis (DCN) and the inner axes are ICI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic variant: any (pods, data, model) factorization whose product
    matches the available device count."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh():
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))
