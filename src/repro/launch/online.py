"""Train-while-serve driver: the online improvement loop on a live server.

  PYTHONPATH=src python -m repro.launch.online --model dnnweaver \
      --waves 6 --wave-size 16 [--generations 3] [--corrupt-step N]

Hosts one engine behind the production front end (`ServeFrontend`), wires
the `OnlineLoop` trainer onto it (harvest unsatisfied requests -> mine
hard examples -> incremental train -> checkpoint -> lock-disciplined hot
swap), and pushes waves of deliberately hard requests (tight objective
slack) while the trainer improves the generator between waves.  Each wave
uses fresh seeds, so nothing is answered from the cache and the reported
satisfied counts track the *current* generation's quality.

``--corrupt-step N`` flips payload bytes in generation N's checkpoint
right after it is written (`repro.serve.faults.corrupt_checkpoint`): the
swap's read-back detects the damage and serving falls back to the
previous good generation — the recovery path the soak harness
(`benchmarks/bench_online.py`) gates on.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel
from repro.serve import (DSEServer, FrontendConfig, OnlineConfig, OnlineLoop,
                         ServeConfig, ServeFrontend, corrupt_checkpoint)

MODELS = {m.name: m for m in (DnnWeaverModel, Im2colModel, TpuMeshModel)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="dnnweaver", choices=sorted(MODELS))
    ap.add_argument("--waves", type=int, default=6,
                    help="request waves pushed through the front end")
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--neurons", type=int, default=64)
    ap.add_argument("--data", type=int, default=512)
    ap.add_argument("--slack", type=float, default=1.05,
                    help="objective slack upper bound; close to 1.0 makes "
                         "requests hard (Pareto-adjacent objectives)")
    ap.add_argument("--generations", type=int, default=0,
                    help="stop training after N generations (0 = no cap)")
    ap.add_argument("--min-hard", type=int, default=8,
                    help="buffered hard tasks that trigger a generation")
    ap.add_argument("--train-iters", type=int, default=4)
    ap.add_argument("--replay", type=int, default=64)
    ap.add_argument("--keep-last-n", type=int, default=3)
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint directory (default: a temp dir)")
    ap.add_argument("--corrupt-step", type=int, default=-1,
                    help="inject corruption into generation N's checkpoint "
                         "after saving (-1 = never): exercises the "
                         "fall-back-to-previous-generation swap path")
    ap.add_argument("--threshold", type=float, default=0.1)
    ap.add_argument("--max-candidates", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    model = MODELS[args.model]()
    gan_cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=args.layers, neurons=args.neurons, batch_size=64)
    engine = GANDSE(model, gan_cfg,
                    ExplorerConfig(prob_threshold=args.threshold,
                                   max_candidates=args.max_candidates))
    ds = generate_dataset(model, args.data, seed=args.seed)
    init_key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 3)
    engine.attach(ds, G.init_generator(init_key, gan_cfg, model.space))

    srv = DSEServer(ServeConfig(max_batch=args.max_batch))
    srv.register(engine)

    ckpt_dir = args.checkpoint_dir or tempfile.mkdtemp(prefix="dse_online_")

    def post_checkpoint(sdir: str) -> None:
        if args.corrupt_step >= 0 and \
                sdir.endswith(f"step_{args.corrupt_step:09d}"):
            corrupt_checkpoint(sdir, seed=args.seed)
            print(f"[online] injected corruption into {sdir}")

    ocfg = OnlineConfig(min_hard=args.min_hard,
                        train_iters=args.train_iters,
                        replay_capacity=args.replay,
                        keep_last_n=args.keep_last_n,
                        max_generations=args.generations,
                        seed=args.seed,
                        post_checkpoint=post_checkpoint)

    n = args.wave_size
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    sat_per_wave = []
    with ServeFrontend(srv, FrontendConfig()) as fe:
        with OnlineLoop(fe, model.name, ckpt_dir, cfg=ocfg) as loop:
            loop.warmup()            # compile the epoch fn up front
            for w in range(args.waves):
                tasks = generate_tasks(model, n, seed=args.seed + 10 + w,
                                       slack=(1.0, args.slack))
                base = int(rng.integers(1 << 20)) * 1000
                futs = [fe.submit(model.name, tasks.net_idx[i],
                                  tasks.lat_obj[i], tasks.pow_obj[i],
                                  seed=base + i) for i in range(n)]
                responses = [f.result(timeout=300) for f in futs]
                sat = sum(1 for r in responses
                          if r.ok and r.result.satisfied)
                sat_per_wave.append(sat)
                m = loop.metrics()
                print(f"[online] wave={w} satisfied={sat}/{n} "
                      f"generation={m['generation']} "
                      f"serving_step={m['serving_step']} "
                      f"buffered={m['buffer']['size']} "
                      f"swaps={m['swaps']} "
                      f"fallbacks={m['swap_fallbacks']}")
                # let the trainer catch up between waves so later waves
                # are served by later generations
                deadline = time.time() + 60
                while ((len(loop.buffer) >= ocfg.min_hard or loop.training)
                       and time.time() < deadline
                       and not (args.generations > 0
                                and loop.generation >= args.generations)):
                    time.sleep(0.05)
            final = loop.metrics()
    dt = time.time() - t0

    s = srv.summary()
    print(f"[online] model={model.name} waves={args.waves} "
          f"satisfied/wave={sat_per_wave} "
          f"generations={final['generations']} swaps={final['swaps']} "
          f"fallbacks={final['swap_fallbacks']} "
          f"errors={final['generation_errors']} "
          f"mined={final['mined_rows']} "
          f"stale_cache_skips={s['stale_cache_skips']} "
          f"invalidations={s['cache']['invalidations']} "
          f"params_gen={s['params_generation']} "
          f"checkpoints={final['checkpoint_steps']} "
          f"wall={dt:.1f}s ckpt_dir={ckpt_dir}")
    assert final["generation_errors"] == 0, final
    assert s["pending"] == 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
