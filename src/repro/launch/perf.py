import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: sweep the optimization knobs for one
(arch x shape) cell, re-lower + re-analyse, and log
hypothesis -> change -> before/after rows.

  PYTHONPATH=src python -m repro.launch.perf --arch deepseek-coder-33b \
      --shape train_4k [--mesh single] --sweep micro=1,2,4,8 fsdp=0,1 \
      act=model,seq,none remat=0,1 --out results/perf_<arch>.jsonl
"""
import argparse
import itertools
import json
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import model_flops_for
from repro.train import step as TS
from repro.utils import roofline as RL


def run_variant(m, shape, mesh, chips, *, micro, fsdp, act, remat) -> dict:
    rec = dict(micro=micro, fsdp=fsdp, act=act, remat=remat)
    t0 = time.time()
    try:
        case = TS.build_case(m, shape, mesh, microbatches=micro,
                             fsdp=bool(fsdp), act_shard=act,
                             remat=bool(remat))
        with mesh:
            compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                               donate_argnums=case.donate_argnums
                               ).lower(*case.args).compile()
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        rl = RL.from_compiled(case.name, compiled, hlo, chips,
                              model_flops=model_flops_for(m, shape,
                                                          case.args[0]))
        rec.update(
            status="ok",
            bytes_per_device=int(mem.temp_size_in_bytes
                                 + mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 - mem.alias_size_in_bytes),
            t_compute_s=rl.t_compute, t_memory_s=rl.t_memory,
            t_collective_s=rl.t_collective, t_bound=rl.t_bound,
            bottleneck=rl.bottleneck, mfu_bound=rl.mfu_bound,
            coll_bytes=rl.coll_bytes, flops=rl.flops, hbm_bytes=rl.hbm_bytes,
            compile_s=round(time.time() - t0, 1),
        )
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {str(e)[:300]}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--mesh-shape", default=None,
                    help="elastic single-pod mesh, e.g. 32x8 (data x model)")
    ap.add_argument("--micro", default="1")
    ap.add_argument("--fsdp", default="1")
    ap.add_argument("--act", default="model")
    ap.add_argument("--remat", default="1")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.mesh_shape:
        from repro.launch.mesh import make_mesh
        d, mm = (int(x) for x in args.mesh_shape.split("x"))
        mesh = make_mesh((d, mm), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    chips = int(np.prod(list(mesh.shape.values())))
    m = configs.get_arch(args.arch)
    shape = SHAPES[args.shape]
    out = args.out or f"results/perf_{configs.canonical(args.arch)}_{args.shape}.jsonl"
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)

    grid = itertools.product(
        [int(x) for x in args.micro.split(",")],
        [int(x) for x in args.fsdp.split(",")],
        args.act.split(","),
        [int(x) for x in args.remat.split(",")],
    )
    with open(out, "a") as f:
        for micro, fsdp, act, remat in grid:
            rec = run_variant(m, shape, mesh, chips, micro=micro, fsdp=fsdp,
                              act=act, remat=remat)
            rec.update(arch=args.arch, shape=args.shape, mesh=args.mesh)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            if rec["status"] == "ok":
                print(f"[perf] micro={micro} fsdp={fsdp} act={act} "
                      f"remat={remat}: t_bound={rec['t_bound']:.4f}s "
                      f"({rec['bottleneck']}) mfu<={rec['mfu_bound']:.3f} "
                      f"mem={rec['bytes_per_device']/1e9:.1f}GB "
                      f"coll={rec['coll_bytes']/1e9:.2f}GB", flush=True)
            else:
                print(f"[perf] micro={micro} fsdp={fsdp} act={act} "
                      f"remat={remat}: FAIL {rec['error']}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
