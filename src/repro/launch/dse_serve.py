"""DSE serving driver: micro-batching loop over a request queue.

  PYTHONPATH=src python -m repro.launch.dse_serve --model im2col \
      --requests 64 --max-batch 16 [--concurrent]

The DSE twin of `repro.launch.serve` (the LM continuous-batching driver):
requests are admitted into a `DSEServer`, coalesced into pow2-bucketed
micro-batches, dispatched through the engine's batched exploration path,
and answered with per-request `DSEResult`s.  A random-init generator is
attached by default (serving throughput does not depend on training
quality); pass --train-iters to train first and report real satisfied
counts.

``--concurrent`` serves the same workload through the production front
end (`repro.serve.frontend.ServeFrontend`): non-blocking submits with
futures, continuous batching overlapping host-side batch formation with
in-flight device compute, and admission control — pair with --max-queue
(bounded queues, shed-at-the-door) and --deadline-s (per-request
deadlines) to see load shedding in the report.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE, summarize
from repro.core.explorer import ExplorerConfig
from repro.core.selector import set_select_route
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel
from repro.serve import DSEServer, ServeConfig

MODELS = {m.name: m for m in (DnnWeaverModel, Im2colModel, TpuMeshModel)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="im2col", choices=sorted(MODELS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--neurons", type=int, default=64)
    ap.add_argument("--data", type=int, default=512)
    ap.add_argument("--train-iters", type=int, default=0,
                    help="0 = attach a random-init G (throughput only)")
    ap.add_argument("--threshold", type=float, default=0.1)
    ap.add_argument("--max-candidates", type=int, default=2048)
    ap.add_argument("--cache", type=int, default=4096,
                    help="LRU result-cache capacity; 0 disables")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    help="fraction of requests re-submitted verbatim "
                         "(exercises the cache/coalescing path)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fused", choices=("auto", "on", "off"), default="auto",
                    help="Pallas fused-MLP dispatch: auto = backend rule "
                         "(TPU on, CPU/GPU off), on/off force it")
    ap.add_argument("--batch-route", choices=("fused", "dense"),
                    default="fused",
                    help="batched selection: fused streaming tiles "
                         "(default) or the dense reference route")
    ap.add_argument("--select-route", choices=("auto", "host", "device"),
                    default="auto",
                    help="per-task select() fallback route: auto = the "
                         "selector.JAX_MIN_CANDIDATES crossover, host/"
                         "device force one (see set_select_route)")
    ap.add_argument("--concurrent", action="store_true",
                    help="serve through the threaded production front end "
                         "(futures + continuous batching) instead of the "
                         "sync submit/drain pump")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="per-model admission bound; submissions past it "
                         "are REJECTED with a retry-after hint (0 = "
                         "unbounded)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline for --concurrent; expired "
                         "requests are shed before dispatch (0 = none)")
    args = ap.parse_args(argv)
    use_fused = {"auto": None, "on": True, "off": False}[args.fused]
    set_select_route(args.select_route)

    model = MODELS[args.model]()
    gan_cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=args.layers, neurons=args.neurons, batch_size=64)
    engine = GANDSE(model, gan_cfg,
                    ExplorerConfig(prob_threshold=args.threshold,
                                   max_candidates=args.max_candidates,
                                   batch_route=args.batch_route))
    if args.train_iters > 0:
        engine.train(args.data, args.train_iters, seed=args.seed)
    else:
        ds = generate_dataset(model, args.data, seed=args.seed)
        init_key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 3)
        engine.attach(ds, G.init_generator(init_key, gan_cfg, model.space))

    srv = DSEServer(ServeConfig(max_batch=args.max_batch,
                                cache_capacity=args.cache,
                                max_queue=args.max_queue,
                                use_fused=use_fused))
    srv.register(engine)

    n = args.requests
    tasks = generate_tasks(model, n, seed=args.seed + 2)
    n_rep = int(n * args.repeat_frac)
    # warmup: a full micro-batch compiles the pow2(max_batch) bucket the
    # timed dispatches will actually use (off-range seeds, cache cleared,
    # so no timed request is answered from warmup work)
    for i in range(min(args.max_batch, n)):
        srv.submit(model.name, tasks.net_idx[i % n], tasks.lat_obj[i % n],
                   tasks.pow_obj[i % n], seed=args.seed - 1_000_000 - i)
    srv.drain()
    srv.cache.clear()

    fe_line = ""
    t0 = time.time()
    if args.concurrent:
        from repro.serve import FrontendConfig, ServeFrontend
        timeout_s = args.deadline_s if args.deadline_s > 0 else None

        def push(fe, rows):
            return [fe.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                              tasks.pow_obj[i], seed=args.seed + i,
                              timeout_s=timeout_s) for i in rows]

        with ServeFrontend(srv, FrontendConfig()) as fe:
            # duplicates submitted while the originals are in flight
            # coalesce (or hit the cache, depending on dispatch timing)...
            futs = push(fe, range(n)) + push(fe, range(n_rep))
            responses = [f.result(timeout=300) for f in futs]
            # ...and verbatim repeats of served requests hit the LRU cache
            responses += [f.result(timeout=300)
                          for f in push(fe, range(n_rep))]
            m = fe.metrics()["frontend"]["latency"]
            fe_line = (f"p50={m['p50_ms']:.1f}ms p99={m['p99_ms']:.1f}ms "
                       f"rejected={srv.stats['rejected']} "
                       f"degraded={srv.stats['degraded_entered']} ")
    else:
        for i in range(n):
            srv.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                       tasks.pow_obj[i], seed=args.seed + i)
        # duplicates of still-queued requests coalesce (dispatch once)...
        for i in range(n_rep):
            srv.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                       tasks.pow_obj[i], seed=args.seed + i)
        responses = srv.drain()
        # ...and verbatim repeats of served requests hit the LRU cache
        for i in range(n_rep):
            srv.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                       tasks.pow_obj[i], seed=args.seed + i)
        responses += srv.drain()
    dt = time.time() - t0

    n_total = n + 2 * n_rep
    s = srv.summary()
    served = [r.result for r in responses if r.ok]
    stats = summarize(served)
    print(f"[dse_serve] model={model.name} "
          f"mode={'concurrent' if args.concurrent else 'sync'} "
          f"kernels={s['kernels']['backend']}:"
          f"{'fused' if s['kernels']['fused'][model.name] else 'jnp'} "
          f"requests={len(responses)}/{n_total} served={len(served)} "
          f"batches={s['batches']} mean_batch={s['mean_batch_size']:.1f} "
          f"coalesced={s['coalesced']} cache_hits={s['cache']['hits']} "
          f"satisfied={stats['n_satisfied']} {fe_line}"
          f"req/s={len(responses)/max(dt, 1e-9):.0f}")
    assert len(responses) == n_total   # every request terminated
    assert s["pending"] == 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
