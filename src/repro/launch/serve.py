"""Batched serving driver: continuous-batching loop over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --requests 16 --max-new 32

A minimal production-shaped server: requests (prompt token lists) are
admitted into a fixed set of batch slots; every engine iteration runs one
batched decode step; finished sequences free their slot for the next
queued request (continuous batching).  Prefill is per-request (chunked
into the shared KV cache by running decode over the prompt — simple, and
identical math to a dedicated prefill pass).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import base as MB
from repro.train import step as TS


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Fixed-slot continuous batching engine."""

    def __init__(self, m, params, batch_slots: int, cache_len: int,
                 mesh=None, eos: Optional[int] = None):
        self.m = m
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pending: List[int] = []           # per-slot prompt cursor
        self.cache_len = cache_len
        self.eos = eos
        self.states = MB.init_decode_state(params, m, batch_slots, cache_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(TS.make_decode_step(m, mesh=mesh))
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                # reset this slot's state lazily: positions restart, and the
                # causal mask ignores stale cache beyond `len`
                self.states = jax.tree.map(
                    lambda st: st.at[...].set(st) if False else st, self.states)

    def step(self):
        """One engine iteration: every active slot advances one token."""
        self._admit()
        toks = np.zeros((len(self.slots), 1), np.int32)
        active = False
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            active = True
            cursor = int(self.pos[i])
            if cursor < len(req.prompt):
                toks[i, 0] = req.prompt[cursor]
            else:
                toks[i, 0] = req.out[-1] if req.out else req.prompt[-1]
        if not active:
            return False
        # NOTE: slots share one `pos` scalar per step in this minimal engine;
        # we use the max cursor (positions only matter relatively within a
        # slot's stream since each slot's KV was written at its own steps).
        pos = jnp.int32(int(self.pos.max()))
        logits, self.states = self._decode(self.params, jnp.asarray(toks),
                                           pos, self.states)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            if self.pos[i] >= len(req.prompt):       # generating
                tok = int(nxt[i])
                req.out.append(tok)
                if len(req.out) >= req.max_new or (self.eos is not None
                                                   and tok == self.eos):
                    req.done = True
                    self.finished.append(req)
                    self.slots[i] = None
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1
        return it


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    m = configs.get_reduced(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = MB.init_params(rng, m)
    eng = Engine(m, params, args.slots, args.cache_len)

    np_rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for r in range(args.requests):
        prompt = np_rng.integers(0, m.vocab, size=args.prompt_len).tolist()
        eng.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    iters = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in eng.finished)
    print(f"[serve] arch={m.name} requests={len(eng.finished)}/{args.requests} "
          f"engine_iters={iters} new_tokens={toks} "
          f"tok/s={toks/max(dt,1e-9):.1f}")
    assert len(eng.finished) == args.requests
    return 0


if __name__ == "__main__":
    sys.exit(main())
