"""Batched serving driver: continuous-batching loop over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --requests 16 --max-new 32

A minimal production-shaped server: requests (prompt token lists) are
admitted into a fixed set of batch slots; every engine iteration runs one
batched decode step; finished sequences free their slot for the next
queued request (continuous batching).  Prefill is per-request (chunked
into the shared KV cache by running decode over the prompt — simple, and
identical math to a dedicated prefill pass).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import base as MB
from repro.train import step as TS


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _recurrent_template(states, m):
    """The recurrent (ssm / xLSTM) portion of a freshly-initialized decode
    state, per segment/spec; None where a spec carries no recurrent state.
    Holding these leaves is cheap — KV caches are excluded (the per-lane
    `start` mask handles them), and at init time they alias the live
    state."""
    out = []
    for seg_st, seg in zip(states, m.segments):
        out.append([
            (st.get("ssm") if isinstance(st, dict) else None)
            if spec.kind in ("dense", "dec") else st
            for st, spec in zip(seg_st, seg.pattern)
        ])
    return out


def _reset_recurrent_lane(states, fresh, m, lane: int):
    """Re-initialize lane `lane` of the per-lane recurrent decode state
    when its batch slot is reused for a new request, by scattering the
    lane slice of the canonical fresh state (`_recurrent_template`) — one
    source of truth with `spec_state_init`/`ssm_decode_init`, whatever
    their init constants.  State leaves are stacked (repeats, batch, ...),
    so lane resets index axis 1.  KV caches need no copy: the per-lane
    `start` mask passed to the decode step hides a reused lane's stale
    entries (see `decode_attention`)."""
    def scatter(st, fr):
        return jax.tree.map(lambda a, f: a.at[:, lane].set(f[:, lane]),
                            st, fr)

    new_states = []
    for seg_st, seg_fr, seg in zip(states, fresh, m.segments):
        new_seg = []
        for st, fr, spec in zip(seg_st, seg_fr, seg.pattern):
            if spec.kind in ("dense", "dec"):
                if fr is not None:
                    st = dict(st, ssm=scatter(st["ssm"], fr))
            else:
                st = scatter(st, fr)
            new_seg.append(st)
        new_states.append(new_seg)
    return new_states


class Engine:
    """Fixed-slot continuous batching engine.

    Every decode step advances the shared clock by one: each layer's KV
    cache writes slot `clock`, and the RoPE position equals the clock, so
    positions stay monotonic for every stream and relative offsets within
    a stream are exact.  Reusing a slot for a new request records the
    admission clock in ``start[slot]``; the decode step masks cache
    entries before it (the previous occupant's), so a reused slot computes
    exactly what a fresh engine would.
    """

    def __init__(self, m, params, batch_slots: int, cache_len: int,
                 mesh=None, eos: Optional[int] = None):
        self.m = m
        self.params = params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache_len = cache_len
        self.eos = eos
        self.states = MB.init_decode_state(params, m, batch_slots, cache_len)
        self._fresh_recurrent = _recurrent_template(self.states, m)
        self.pos = np.zeros(batch_slots, np.int32)  # per-slot prompt cursor
        self.clock = 0                 # == every layer state's `len`
        # non-windowed attention writes KV at slot `clock`: once the clock
        # reaches the cache span, writes clamp onto the last slot and decode
        # is silently wrong — fail loudly instead.  Windowed-only models
        # (ring buffers) have no such horizon.
        self._kv_horizon = cache_len if any(
            sp.kind in ("dense", "dec") and sp.cfg.window is None
            for seg in m.segments for sp in seg.pattern) else None
        self.start = np.zeros(batch_slots, np.int32)  # per-slot stream start
        self._decode = jax.jit(TS.make_decode_step(m, mesh=mesh))
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                # stale-state reset: mask the previous occupant's KV range
                # [0, clock) out of this lane's attention, and re-init its
                # recurrent (ssm/xLSTM) cells
                self.start[i] = self.clock
                self.states = _reset_recurrent_lane(
                    self.states, self._fresh_recurrent, self.m, i)

    def step(self):
        """One engine iteration: every active slot advances one token."""
        self._admit()
        toks = np.zeros((len(self.slots), 1), np.int32)
        active = False
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            active = True
            cursor = int(self.pos[i])
            if cursor < len(req.prompt):
                toks[i, 0] = req.prompt[cursor]
            else:
                toks[i, 0] = req.out[-1] if req.out else req.prompt[-1]
        if not active:
            return False
        if self._kv_horizon is not None and self.clock >= self._kv_horizon:
            raise RuntimeError(
                f"KV capacity exhausted: engine clock {self.clock} reached "
                f"cache_len {self._kv_horizon} (global-attention caches are "
                f"append-only across the engine's whole lifetime); size "
                f"cache_len for total engine steps, not per-request length")
        # slots share one position scalar per step: the engine clock.  A
        # stream admitted at clock t0 sees positions t0..t0+n — offset by
        # t0 from a fresh engine, which RoPE's relative encoding cancels.
        logits, self.states = self._decode(self.params, jnp.asarray(toks),
                                           jnp.int32(self.clock), self.states,
                                           start=jnp.asarray(self.start))
        self.clock += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            if self.pos[i] >= len(req.prompt):       # generating
                tok = int(nxt[i])
                req.out.append(tok)
                if len(req.out) >= req.max_new or (self.eos is not None
                                                   and tok == self.eos):
                    req.done = True
                    self.finished.append(req)
                    self.slots[i] = None
        return True

    def run(self, max_iters: int = 10_000):
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self.step()
            it += 1
        return it


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    m = configs.get_reduced(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = MB.init_params(rng, m)
    eng = Engine(m, params, args.slots, args.cache_len)

    np_rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for r in range(args.requests):
        prompt = np_rng.integers(0, m.vocab, size=args.prompt_len).tolist()
        eng.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    iters = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in eng.finished)
    print(f"[serve] arch={m.name} requests={len(eng.finished)}/{args.requests} "
          f"engine_iters={iters} new_tokens={toks} "
          f"tok/s={toks/max(dt,1e-9):.1f}")
    assert len(eng.finished) == args.requests
    return 0


if __name__ == "__main__":
    sys.exit(main())
