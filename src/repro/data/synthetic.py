"""Deterministic synthetic LM data pipeline.

Tokens are a pure function of (seed, step, global row index) via a
counter-based hash, so:
  * every data-parallel shard generates exactly its own rows (sharded by
    the (pod, data) mesh coordinates — no host-side data redistribution);
  * a restarted job replays the same batches from the checkpointed step
    (restart-reproducibility is tested in tests/test_checkpoint.py).

The stream mimics a Zipf-ish unigram LM plus a deterministic "copy motif"
so cross-entropy decreases visibly during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xorshift-multiply counter hash (vectorized, uint32)."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(16))) * np.uint64(0x7FEB352D)
    x = (x ^ (x >> np.uint64(15))) * np.uint64(0x846CA68B)
    x = x ^ (x >> np.uint64(16))
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_s: float = 1.2
    motif_period: int = 16


class SyntheticStream:
    """batch(step, shard_index, n_shards) -> (tokens, labels) numpy arrays
    of the shard's rows for that step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_s)
        self._cdf = np.cumsum(probs / probs.sum())

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        c = self.cfg
        # counter = seed * P1 + step * P2 + row * L + pos
        pos = np.arange(c.seq_len + 1, dtype=np.uint64)[None, :]
        ctr = (np.uint64(c.seed) * np.uint64(0x9E3779B1)
               + np.uint64(step) * np.uint64(0x85EBCA77)
               + rows.astype(np.uint64)[:, None] * np.uint64(c.seq_len + 1)
               + pos)
        u = _hash_u32(ctr).astype(np.float64) / 2**32
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.minimum(toks, c.vocab - 1)
        # deterministic copy motif: position p copies p - period when the
        # row-hash says so (gives the model something learnable)
        copy_mask = (_hash_u32(ctr + np.uint64(0xABCD)) & 3) == 0
        p = c.motif_period
        out = toks.copy()
        for start in range(p, c.seq_len + 1, p):
            seg = slice(start, min(start + p, c.seq_len + 1))
            src = slice(start - p, start - p + (seg.stop - seg.start))
            out[:, seg] = np.where(copy_mask[:, seg], out[:, src], toks[:, seg])
        return out

    def batch(self, step: int, shard_index: int = 0, n_shards: int = 1
              ) -> Tuple[np.ndarray, np.ndarray]:
        c = self.cfg
        assert c.global_batch % n_shards == 0
        rows_per = c.global_batch // n_shards
        rows = (np.arange(rows_per, dtype=np.uint64)
                + np.uint64(shard_index * rows_per))
        toks = self._tokens(step, rows)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
