"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in
tests/test_hlo_cost.py), which undercounts every scanned model (layers
scan x microbatch scan x attention block scans) by orders of magnitude.
This module re-derives the roofline inputs from the compiled HLO text,
walking the call graph and multiplying through loop trip counts
(``backend_config={"known_trip_count":{"n":...}}`` emitted by XLA):

  * flops            — 2 x |result| x |contracted dims| for every `dot`
  * hbm bytes        — operand + result bytes of every top-level op in
                       non-fused computations (post-fusion buffer traffic)
  * collective bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

Edges: while -> (body, cond) x trip_count; fusion -> called computation
(flops recursed, bytes NOT — fusion internals never touch HBM);
conditional branches counted once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",") if d)
            out.append((dt, shape))
    return out


def _numel(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * _numel(s) for dt, s in _shape_list(type_str))


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    type_str: str           # result type text
    operands: List[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    shapes: Dict[str, str]  # symbol -> result type text


def _parse_computations(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
            if m and not line.startswith(" "):
                cur = _Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rhs = m.groups()
        # result type = leading type expr, op kind = first word after it
        tm = re.match(r"((?:\([^)]*\)|[\w\[\],]+)(?:\{[^}]*\})?)\s+([\w\-]+)", rhs)
        if not tm:
            continue
        type_str, kind = tm.groups()
        paren = rhs.find("(", tm.start(2))
        operands = []
        if paren >= 0:
            depth, j = 0, paren
            while j < len(rhs):
                if rhs[j] == "(":
                    depth += 1
                elif rhs[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
                j += 1
            operands = _OPND_RE.findall(rhs[paren:j + 1])
        op = _Op(name, kind, type_str, operands, s)
        cur.ops.append(op)
        cur.shapes[name] = type_str
    return comps, entry


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    res = _shape_list(op.type_str)
    if not res:
        return 0.0
    result_n = _numel(res[0][1])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    k = 1
    if op.operands:
        lhs_type = shapes.get(op.operands[0])
        if lhs_type:
            ls = _shape_list(lhs_type)
            if ls:
                for d in cdims:
                    if d < len(ls[0][1]):
                        k *= ls[0][1][d]
    return 2.0 * result_n * max(k, 1)


def analyze(text: str) -> Dict[str, float]:
    comps, entry = _parse_computations(text)
    if entry is None:
        for name in comps:
            if "main" in name or "entry" in name.lower():
                entry = name
                break
    totals = {"flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0, "n_coll": 0.0}
    for k in _COLLECTIVES:
        totals[f"coll_{k}"] = 0.0
    if entry is None:
        return totals

    def visit(name: str, mult: float, fused: bool, seen: Tuple[str, ...]):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen + (name,)
        for op in comp.ops:
            if op.kind == "dot":
                totals["flops"] += mult * _dot_flops(op, comp.shapes)
            if op.kind in _COLLECTIVES:
                b = _bytes_of(op.type_str)
                totals["coll_bytes"] += mult * b
                totals[f"coll_{op.kind}"] += mult * b
                totals["n_coll"] += mult
            if not fused and op.kind not in _SKIP_BYTES:
                b = _bytes_of(op.type_str)
                for o in op.operands:
                    t = comp.shapes.get(o)
                    if t:
                        b += _bytes_of(t)
                totals["hbm_bytes"] += mult * b
            # edges
            if op.kind == "while":
                tm = _TRIP_RE.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
                for target in _CALLS_RE.findall(op.line):
                    visit(target, mult * trip, fused, seen)
            elif op.kind in ("fusion",):
                for target in _CALLS_RE.findall(op.line):
                    visit(target, mult, True, seen)
            elif op.kind in ("call", "conditional", "custom-call",
                             "reduce", "scatter", "sort", "map",
                             "reduce-window", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                for target in _CALLS_RE.findall(op.line):
                    # tiny scalar appliers; recurse for dots only
                    visit(target, mult, True, seen)
        return

    visit(entry, 1.0, False, ())
    return totals


def top_ops(text: str, n: int = 20) -> List[Dict[str, object]]:
    """The n heaviest ops by loop-multiplied bytes — the §Perf profile."""
    comps, entry = _parse_computations(text)
    rows: List[Dict[str, object]] = []

    def visit(name: str, mult: float, fused: bool, seen):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        seen = seen + (name,)
        for op in comp.ops:
            if not fused and op.kind not in _SKIP_BYTES:
                b = _bytes_of(op.type_str)
                for o in op.operands:
                    t = comp.shapes.get(o)
                    if t:
                        b += _bytes_of(t)
                meta = re.search(r'op_name="([^"]*)"', op.line)
                rows.append({
                    "bytes": mult * b, "mult": mult, "kind": op.kind,
                    "comp": name,
                    "op_name": meta.group(1) if meta else op.name,
                    "shape": op.type_str.split("{")[0],
                })
            if op.kind == "while":
                tm = _TRIP_RE.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
                for target in _CALLS_RE.findall(op.line):
                    visit(target, mult * trip, fused, seen)

    if entry:
        visit(entry, 1.0, False, ())
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:n]
