"""Three-term roofline from a compiled dry-run artifact (TPU v5e target).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

cost_analysis() reports whole-program FLOPs/bytes (already per the full
mesh program; XLA reports per-device numbers for SPMD modules), and the
collective bytes come from the HLO parse (utils/hlo.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_LINK_BW = 50e9                # B/s per link


@dataclasses.dataclass
class Roofline:
    name: str
    flops: float                  # HLO FLOPs (per device)
    hbm_bytes: float              # HLO bytes accessed (per device)
    coll_bytes: float             # collective bytes (per device)
    chips: int
    model_flops: float = 0.0      # 6*N*D useful FLOPs (whole step, global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (s): overlapped model -> max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / (chips * HLO_FLOPs): how much compiled compute is
        useful (catches remat / redundancy waste)."""
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops * self.chips, 1.0)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Upper bound on MFU at the roofline step time."""
        if not self.model_flops:
            return None
        return self.model_flops / (self.t_bound * self.chips * PEAK_FLOPS_BF16)

    def row(self) -> Dict[str, object]:
        return {
            "case": self.name,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "useful_ratio": (round(self.useful_ratio, 4)
                             if self.useful_ratio is not None else None),
            "mfu_bound": (round(self.mfu_bound, 4)
                          if self.mfu_bound is not None else None),
        }


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6*N*D for one training step."""
    return 6.0 * n_params_active * tokens


def model_flops_forward(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens


def from_compiled(name: str, compiled, hlo_text: str, chips: int,
                  model_flops: float = 0.0) -> Roofline:
    """Roofline terms from the compiled artifact.

    Uses the loop-aware HLO cost model (utils/hlo_cost.py): XLA's own
    ``cost_analysis()`` counts while-loop bodies once, which undercounts
    scanned models (layers scan x microbatch scan) by orders of magnitude
    — verified in tests/test_hlo_cost.py.
    """
    from repro.utils.hlo_cost import analyze
    t = analyze(hlo_text)
    return Roofline(name=name, flops=t["flops"], hbm_bytes=t["hbm_bytes"],
                    coll_bytes=t["coll_bytes"], chips=chips,
                    model_flops=model_flops)
