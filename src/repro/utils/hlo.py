"""HLO text analysis: collective bytes per category.

``cost_analysis()`` does not report collective traffic, so we parse the
(compiled or lowered) HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  Sizes are
computed from the op's *result* shape string (for reduce-scatter the
result is the post-scatter shard; for all-gather the gathered result) —
a consistent, conservative measure of bytes that must cross links.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %x = bf16[2,4096,128]{...} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind (plus 'total')."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            size = sum(_shape_bytes(d, s)
                       for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            size = _shape_bytes(dtype, dims)
        out[kind] += size
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["n_ops"] = sum(count[k] for k in _COLLECTIVES)
    for k in _COLLECTIVES:
        out[f"n_{k}"] = count[k]
    return out
