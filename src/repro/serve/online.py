"""Train-while-serve: the online improvement loop with checkpointed hot-swap.

The paper's negative-sample insight (§6.2: unsatisfied designs are the
informative training signal) extends naturally to serving: every request
the current generator fails to satisfy is a *hard example* the next
training generation should learn from.  This module closes that loop
around a live `ServeFrontend`:

    harvest -> mine -> train -> checkpoint -> swap -> invalidate

- **harvest**: a response listener (`ServeFrontend.add_response_listener`)
  feeds every unsatisfied served request into a bounded `HardTaskBuffer`,
  deduplicated by the request's cache key — the same identity the result
  cache uses, so one hard task is harvested once no matter how often it
  is re-asked;
- **mine**: `mine_hard_examples` turns each hard task into valid
  Algorithm 1 training rows (dataset rows double as (objective, witness)
  pairs — a row's own (L, P) are the objectives it satisfies), by
  sampling configs for the task's network and keeping the least-violating
  feasible ones;
- **train**: the mined rows round-robin into a fixed-size `HardReplay`
  region appended to the base dataset, and `train_gan` runs a few
  incremental epochs warm-started from the previous generation's
  `TrainState` (params, optimizer moments, rng all resume).  The replay
  region is fixed-size *on purpose*: constant data shapes + the memoized
  epoch fn (`repro.core.train._cached_epoch_fn`) make every warm
  generation zero-recompile;
- **checkpoint**: each generation is saved through `CheckpointManager`
  (atomic publish, per-leaf checksums, `keep_last_n` retention) before it
  is ever served;
- **swap**: the new params are read *back from disk* (`restore_latest`)
  and attached via the lock-disciplined `ServeFrontend.swap` — so the
  params being served are, by construction, exactly the params a crash
  restart would recover, and a corrupted save is detected at swap time
  (`CheckpointCorruptionError` inside `restore_latest` skips it) and the
  loop falls back to the previous good generation instead of attaching
  garbage;
- **invalidate**: the swap bumps the model's params generation and drops
  its cache entries (`DSEServer.swap`); a batch executing across the
  swap still answers but cannot re-poison the cache (the stale-stamp
  contract, `MicroBatch.params_gen`).

The trainer runs on one background thread; all its mutable state
(`TrainState`, generation counter, metrics) is touched by that thread
only.  The harvest listener runs on serving threads and touches only the
internally-locked `HardTaskBuffer`, which is the single point of
cross-thread handoff.

`benchmarks/bench_online.py` soaks the loop end to end and gates on the
satisfied-rate of a held-out hard-task stream strictly improving across
generations while serving p99 stays within budget.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.dse_api import cache_key
from repro.core.train import TrainState, train_gan
from repro.dataset.generator import Dataset, DSETask
from repro.serve.frontend import ServeFrontend
from repro.serve.request import DSEResponse


@dataclasses.dataclass
class OnlineConfig:
    """Knobs for the online improvement loop."""

    buffer_capacity: int = 512   # hard-task buffer bound (oldest evicted)
    min_hard: int = 16           # buffered hard tasks that trigger a generation
    train_iters: int = 4         # incremental epochs per generation
    mine_samples: int = 256      # configs sampled per hard task when mining
    mine_per_task: int = 4       # best (least-violating) rows kept per task
    replay_capacity: int = 64    # fixed-size hard-example region appended to
                                 # the base dataset (fixed so data shapes --
                                 # and the jitted epoch -- never change)
    keep_last_n: int = 3         # checkpoint retention (CheckpointManager)
    poll_s: float = 0.02         # trainer idle poll while below min_hard
    train_when_idle: bool = True  # defer a ready generation while requests
                                  # are in flight: on shared hosts the
                                  # trainer competes with dispatch for
                                  # cores, so training in serving gaps is
                                  # what keeps p99 flat (bench_online gates
                                  # on 1.25x of the no-trainer baseline)
    idle_defer_s: float = 2.0    # starvation bound on that deferral: under
                                 # continuous load, train anyway after this
    canary_after_swap: bool = True  # after each swap, push one canary
                                 # request through the front end: the first
                                 # post-swap dispatch pays the device
                                 # transfer of the fresh params, and eating
                                 # it here keeps it out of user-visible p99
    seed: int = 0                # replay init + per-generation train seeds
    max_generations: int = 0     # stop training after N generations (0 = no
                                 # cap; serving continues either way)
    #: fault-injection hook called with the just-saved step dir, after the
    #: checkpoint write and *before* the swap reads it back -- the soak
    #: harness points `repro.serve.faults.corrupt_checkpoint` at it to
    #: prove a torn/corrupted save falls back to the previous generation
    post_checkpoint: Optional[Callable[[str], None]] = None


class HardTaskBuffer:
    """Bounded, deduplicating buffer of hard (unsatisfied) served tasks.

    Thread-safe: offered from serving threads (the response listener),
    drained by the trainer.  Keys on the request's cache key
    (`repro.core.dse_api.cache_key`), so resubmissions of the same task
    are harvested once; at capacity the oldest entry is evicted (newer
    traffic is a better sample of what the current params fail on).
    """

    def __init__(self, capacity: int = 512):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._d: "OrderedDict[Tuple, Tuple[np.ndarray, float, float]]" = \
            OrderedDict()
        self.offered = 0
        self.admitted = 0
        self.deduped = 0
        self.evicted = 0
        self.drained = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def offer(self, resp: DSEResponse) -> bool:
        """Harvest one response; returns True when it was admitted.  Only
        answered-but-unsatisfied responses with task identity qualify
        (FAILED/REJECTED responses carry no result to judge)."""
        with self._lock:
            self.offered += 1
            if (not resp.ok or resp.net_idx is None or resp.seed is None
                    or resp.result.satisfied):
                return False
            key = cache_key(resp.model_name, resp.net_idx,
                            resp.result.lat_obj, resp.result.pow_obj,
                            resp.seed)
            if key in self._d:
                self.deduped += 1
                return False
            self._d[key] = (np.array(resp.net_idx, np.int64, copy=True),
                            float(resp.result.lat_obj),
                            float(resp.result.pow_obj))
            self.admitted += 1
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self.evicted += 1
            return True

    def take_all(self) -> Optional[DSETask]:
        """Drain the buffer into one task batch (None when empty)."""
        with self._lock:
            items = list(self._d.values())
            self._d.clear()
            self.drained += len(items)
        if not items:
            return None
        return DSETask(
            net_idx=np.stack([net for net, _, _ in items]),
            lat_obj=np.asarray([lo for _, lo, _ in items], np.float64),
            pow_obj=np.asarray([po for _, _, po in items], np.float64),
        )

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"size": len(self._d), "capacity": self.capacity,
                    "offered": self.offered, "admitted": self.admitted,
                    "deduped": self.deduped, "evicted": self.evicted,
                    "drained": self.drained}


def _relative_violation(lat: np.ndarray, pw: np.ndarray,
                        lat_obj: float, pow_obj: float) -> np.ndarray:
    """Summed relative objective violation (0 = satisfied); non-finite
    metrics (a design the model cannot realize) score +inf, never 0 --
    the core/selector.py:is_satisfied convention."""
    finite = np.isfinite(lat) & np.isfinite(pw)
    v = (np.maximum(np.where(finite, lat, 0.0) / lat_obj - 1.0, 0.0)
         + np.maximum(np.where(finite, pw, 0.0) / pow_obj - 1.0, 0.0))
    return np.where(finite, v, np.inf)


def mine_hard_examples(model, tasks: DSETask, n_samples: int = 256,
                       per_task: int = 4,
                       rng: Optional[np.random.Generator] = None
                       ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]]:
    """Turn hard tasks into Algorithm 1 training rows.

    For each task, sample ``n_samples`` configs for its network, evaluate
    the design model, and keep the ``per_task`` *least-violating* finite
    rows near the objective frontier.  Every kept row is a valid training
    sample as-is -- in Algorithm 1 a row's own (L, P) are the objectives
    it satisfies exactly -- so the generator is taught witnesses in
    precisely the region it is currently failing to serve.

    Returns ``(net_idx, cfg_idx, latency, power)`` arrays, or None when
    nothing finite was mined (a task whose network admits no finite
    design contributes nothing).
    """
    rng = np.random.default_rng(0) if rng is None else rng
    nets: List[np.ndarray] = []
    cfgs: List[np.ndarray] = []
    lats: List[np.ndarray] = []
    pows: List[np.ndarray] = []
    for i in range(len(tasks)):
        net = np.asarray(tasks.net_idx[i]).reshape(1, -1)
        cfg_idx = model.space.sample_indices(rng, n_samples)
        net_rep = np.repeat(net, n_samples, axis=0)
        lat, pw = model.evaluate_indices(net_rep, cfg_idx)
        viol = _relative_violation(np.asarray(lat, np.float64),
                                   np.asarray(pw, np.float64),
                                   float(tasks.lat_obj[i]),
                                   float(tasks.pow_obj[i]))
        order = np.argsort(viol, kind="stable")[:per_task]
        keep = order[np.isfinite(viol[order])]
        if keep.size == 0:
            continue
        nets.append(net_rep[keep])
        cfgs.append(cfg_idx[keep])
        lats.append(np.asarray(lat)[keep])
        pows.append(np.asarray(pw)[keep])
    if not nets:
        return None
    return (np.concatenate(nets), np.concatenate(cfgs),
            np.concatenate(lats), np.concatenate(pows))


class HardReplay:
    """Fixed-size hard-example region appended to the base dataset.

    Initialized with random base rows (so generation 1 already trains on
    full-shape data) and overwritten round-robin as mined rows arrive.
    ``dataset()`` keeps the base normalizers -- the encoding contract the
    attached explorer was built against -- and always returns arrays of
    size ``base.n + capacity``: constant shapes are what make the
    memoized epoch fn zero-recompile across generations.

    Single-threaded by design: only the trainer thread touches it.
    """

    def __init__(self, base: Dataset, capacity: int = 64, seed: int = 0):
        assert base.n > 0, "empty base dataset"
        self.base = base
        self.capacity = int(capacity)
        rng = np.random.default_rng(seed)
        pick = rng.integers(0, base.n, size=self.capacity)
        self._net = base.net_idx[pick].copy()
        self._cfg = base.cfg_idx[pick].copy()
        self._lat = base.latency[pick].copy()
        self._pow = base.power[pick].copy()
        self._cursor = 0
        self.absorbed = 0

    def mix_in(self, net_idx: np.ndarray, cfg_idx: np.ndarray,
               lat: np.ndarray, pw: np.ndarray) -> int:
        """Write mined rows round-robin into the replay region; returns
        how many were written (past one capacity's worth, newer rows
        overwrite older ones from the same call)."""
        n = int(np.asarray(lat).shape[0])
        for j in range(n):
            i = self._cursor % self.capacity
            self._net[i] = net_idx[j]
            self._cfg[i] = cfg_idx[j]
            self._lat[i] = lat[j]
            self._pow[i] = pw[j]
            self._cursor += 1
        self.absorbed += n
        return n

    def dataset(self) -> Dataset:
        """Base ∪ replay as one Dataset (base normalizers preserved)."""
        return dataclasses.replace(
            self.base,
            net_idx=np.concatenate([self.base.net_idx, self._net]),
            cfg_idx=np.concatenate([self.base.cfg_idx, self._cfg]),
            latency=np.concatenate([self.base.latency, self._lat]),
            power=np.concatenate([self.base.power, self._pow]),
        )


class OnlineLoop:
    """The train-while-serve loop around one hosted model.

    Wire-up: registers a harvest listener on the front end; ``start()``
    writes a generation-0 checkpoint of the currently-attached params
    (so `restore_latest` always has a pre-training fallback) and spawns
    the trainer thread.  Each generation: drain the hard buffer, mine
    training rows, fine-tune warm-started from the previous generation,
    checkpoint, then swap the *restored-from-disk* params in through the
    lock-disciplined `ServeFrontend.swap`.  A corrupted save (injected or
    real) is caught by the restore's checksum validation and serving
    falls back to the previous good generation -- the loop never attaches
    params it could not recover after a crash.

    Use as a context manager, or call ``start()``/``stop()``;
    ``run_generation()`` is callable synchronously (no thread) for tests.
    """

    def __init__(self, frontend: ServeFrontend, model_name: str,
                 checkpoint_dir: str, gan_cfg=None,
                 cfg: Optional[OnlineConfig] = None,
                 base_ds: Optional[Dataset] = None):
        self.cfg = cfg or OnlineConfig()
        self.frontend = frontend
        self.model_name = model_name
        self.engine = frontend.server.engines[model_name]
        self.model = self.engine.model
        self.gan_cfg = gan_cfg if gan_cfg is not None \
            else getattr(self.engine, "gan_cfg", None)
        assert self.gan_cfg is not None, \
            "engine has no gan_cfg; pass gan_cfg= explicitly"
        base = base_ds if base_ds is not None \
            else getattr(self.engine, "ds", None)
        assert base is not None, \
            "engine has no attached dataset; pass base_ds= explicitly"
        self.buffer = HardTaskBuffer(self.cfg.buffer_capacity)
        self.replay = HardReplay(base, capacity=self.cfg.replay_capacity,
                                 seed=self.cfg.seed)
        self.ckpt = CheckpointManager(checkpoint_dir,
                                      keep_last_n=self.cfg.keep_last_n)
        # warm-start source: a train()-ed engine hands over its TrainState
        # (params + optimizer moments resume); an attach()-ed engine has
        # none, so generation 1 initializes fresh inside train_gan
        self._state: Optional[TrainState] = getattr(self.engine, "state",
                                                    None)
        self.generation = 0          # generations trained by this loop
        self.serving_step = None     # checkpoint step currently attached
        self._rng = np.random.default_rng(self.cfg.seed)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._training = False       # trainer mid-generation (flag only:
                                     # written by the trainer thread, read
                                     # by pacing loops like bench_online's
                                     # between-wave catch-up wait)
        self._last_error: Optional[str] = None
        self.counters = {"generations": 0, "swaps": 0, "swap_fallbacks": 0,
                         "generation_errors": 0, "mined_rows": 0,
                         "harvested_batches": 0, "idle_defers": 0,
                         "canaries": 0}
        frontend.add_response_listener(self._harvest)

    # ---- harvest (serving threads) -----------------------------------------
    def _harvest(self, resp: DSEResponse) -> None:
        # runs under the front-end lock: the buffer's own lock is a leaf
        # (never held while taking another), so this cannot deadlock
        if resp.model_name == self.model_name:
            self.buffer.offer(resp)

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> "OnlineLoop":
        if self._thread is not None:
            return self
        # generation 0: checkpoint the params being served *before* any
        # training, so restore_latest always has a fallback even if every
        # later save is damaged (skipped when resuming an existing dir)
        params = getattr(self.engine, "g_params", None)
        if params is not None and self.ckpt.latest_step() is None:
            self.ckpt.save(0, params, extra={"generation": 0})
        self._stop.clear()
        self._thread = threading.Thread(target=self._run,
                                        name="dse-online-trainer",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 120.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self) -> "OnlineLoop":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.stop()

    def _run(self) -> None:
        ready_since: Optional[float] = None
        while not self._stop.is_set():
            capped = (self.cfg.max_generations > 0
                      and self.generation >= self.cfg.max_generations)
            if not capped and len(self.buffer) >= self.cfg.min_hard:
                if ready_since is None:
                    ready_since = time.monotonic()
                if (self.cfg.train_when_idle
                        and not self.frontend.wait_all(timeout=0.0)
                        and (time.monotonic() - ready_since
                             < self.cfg.idle_defer_s)):
                    # requests in flight: yield the cores to serving and
                    # train in the gap (bounded, so continuous load cannot
                    # starve the trainer forever)
                    self.counters["idle_defers"] += 1
                    self._stop.wait(self.cfg.poll_s)
                    continue
                ready_since = None
                self._training = True
                try:
                    self.run_generation()
                except Exception as e:
                    # the trainer must never die silently mid-soak: count
                    # it, keep serving on the last good generation
                    self.counters["generation_errors"] += 1
                    self._last_error = repr(e)
                finally:
                    self._training = False
            else:
                self._stop.wait(self.cfg.poll_s)

    # ---- the generation step (trainer thread) ------------------------------
    def warmup(self) -> None:
        """Compile the incremental-training epoch before timed serving: one
        throwaway epoch on the replay dataset (fresh init, state discarded)
        traces the exact (model, cfg, shapes) the real generations reuse."""
        train_gan(self.model, self.replay.dataset(), self.gan_cfg,
                  iters=1, seed=self.cfg.seed)

    def run_generation(self) -> bool:
        """One harvest -> mine -> train -> checkpoint -> swap cycle;
        returns True when a generation was trained (False: nothing
        buffered and nothing mined -- no-op)."""
        tasks = self.buffer.take_all()
        if tasks is not None:
            self.counters["harvested_batches"] += 1
            mined = mine_hard_examples(self.model, tasks,
                                       n_samples=self.cfg.mine_samples,
                                       per_task=self.cfg.mine_per_task,
                                       rng=self._rng)
            if mined is not None:
                self.counters["mined_rows"] += self.replay.mix_in(*mined)
        elif self.generation > 0:
            return False        # nothing new to learn from
        self._state = train_gan(self.model, self.replay.dataset(),
                                self.gan_cfg, iters=self.cfg.train_iters,
                                seed=int(self._rng.integers(1 << 31)),
                                state=self._state)
        self.generation += 1
        self.counters["generations"] += 1
        sdir = self.ckpt.save(self.generation, self._state.g_params,
                              extra={"generation": self.generation,
                                     "mined_rows": self.counters["mined_rows"]})
        if self.cfg.post_checkpoint is not None:
            self.cfg.post_checkpoint(sdir)
        self._swap()
        return True

    def _swap(self) -> None:
        """Attach the newest *recoverable* checkpoint: the params are read
        back from disk, so what is served is exactly what a crash restart
        would restore, and a damaged save is detected (checksums) and
        skipped in favor of the previous good generation."""
        restored = self.ckpt.restore_latest(like=self._state.g_params)
        if restored is None:
            self.counters["swap_fallbacks"] += 1
            self._last_error = "no restorable checkpoint; serving unchanged"
            return
        step, params = restored
        if step != self.generation:
            # the just-saved step did not restore (corrupted/torn): an
            # older generation serves instead
            self.counters["swap_fallbacks"] += 1
        self.frontend.swap(self.model_name, self.replay.dataset(), params)
        self.serving_step = step
        self.counters["swaps"] += 1
        if self.cfg.canary_after_swap:
            self._canary()

    def _canary(self) -> None:
        """Pre-warm the freshly attached params through the real serving
        path (a base-dataset row satisfied by construction, under a seed
        no user request uses, so it neither hits the cache nor harvests
        itself as a hard task)."""
        base = self.replay.base
        seed = 2_000_000_000 - self.counters["swaps"]
        try:
            fut = self.frontend.submit(self.model_name, base.net_idx[0],
                                       float(base.latency[0]),
                                       float(base.power[0]), seed=seed)
            fut.result(timeout=30.0)
            self.counters["canaries"] += 1
        except (RuntimeError, FuturesTimeout):
            pass    # front end not running / saturated: strictly best-effort

    # ---- introspection -----------------------------------------------------
    @property
    def training(self) -> bool:
        """True while the trainer thread is mid-generation: pacing loops
        (the launch driver, bench_online's between-wave catch-up) wait on
        this so timed serving windows do not overlap a training burst."""
        return self._training

    def metrics(self) -> Dict:
        return {
            "generation": self.generation,
            "training": self._training,
            "serving_step": self.serving_step,
            "last_error": self._last_error,
            "buffer": self.buffer.stats(),
            "replay": {"capacity": self.replay.capacity,
                       "absorbed": self.replay.absorbed},
            "checkpoint_steps": self.ckpt.steps(),
            **self.counters,
        }
