"""Concurrent production front end over `DSEServer`: non-blocking submit
with futures, continuous batching, admission control, and load shedding.

The sync `DSEServer` is an event-loop pump: submissions and dispatches
interleave on one thread, so a slow dispatch stalls every caller behind
it.  `ServeFrontend` wraps one server with a two-stage pipeline:

- **submitter threads** (any number) call ``submit`` and get a
  ``concurrent.futures.Future`` resolving to the request's `DSEResponse`
  — cache hits and admission rejections resolve immediately;
- a **former** thread continuously sheds expired-deadline requests and
  forms the next pow2-bucketed micro-batch (host-side work: concat,
  padding) into a small bounded buffer;
- a **dispatcher** thread executes buffered batches through the engine
  (``DSEServer.execute_batch``, the only stage that runs *outside* the
  front-end lock) — so host-side batching of micro-batch N+1 overlaps
  with the in-flight device compute of micro-batch N, and submissions
  never wait on a dispatch.

Every submitted request terminates in exactly one of DONE (dispatch /
cache / coalesced), FAILED (engine kept raising past the retry cap), or
REJECTED (queue bound, expired deadline, or shutdown) — the soak harness
(`benchmarks/bench_load.py`) pins "none wedged" under injected faults.

Admission control: with ``ServeConfig.max_queue`` set, a full per-model
queue either rejects at the door (``admission="reject"``, REJECTED with a
retry-after hint — shed load instead of buffering it) or blocks the
submitter until space frees (``admission="block"`` — backpressure).
Deadlines (``timeout_s``) shed still-queued requests at batch formation.
Failure handling — jittered-exponential retry backoff and the degraded
host-route fallback — lives in the server layer and works identically
here; the dispatcher simply records failures and moves on instead of
re-raising.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import (Callable, Deque, Dict, Iterable, List, Optional, Tuple,
                    TYPE_CHECKING)

import numpy as np

from repro.serve.batcher import MicroBatch
from repro.serve.request import SOURCE_REJECTED, DSEResponse
from repro.serve.server import DSEServer, _now

if TYPE_CHECKING:
    from repro.dataset.generator import Dataset


@dataclasses.dataclass
class FrontendConfig:
    admission: str = "reject"    # full-queue policy: "reject" sheds at the
                                 # door, "block" backpressures the submitter
                                 # (only meaningful with ServeConfig.max_queue)
    default_timeout_s: Optional[float] = None  # per-request deadline applied
                                 # when submit() gets no explicit timeout_s
                                 # (None = no deadline)
    max_prepared: int = 2        # formed micro-batches buffered ahead of the
                                 # dispatcher — the batching/compute overlap
                                 # window (1 = form strictly one ahead)
    idle_sleep_s: float = 0.002  # former poll while queues are empty/backing
                                 # off (submit() wakes it immediately)
    latency_window: int = 4096   # submit->response samples kept for p50/p99


def _percentiles(samples: Iterable[float]) -> Dict[str, float]:
    if not samples:
        return {"n": 0, "p50_ms": float("nan"), "p99_ms": float("nan"),
                "mean_ms": float("nan"), "max_ms": float("nan")}
    a = np.asarray(samples, np.float64) * 1e3
    return {"n": int(a.size), "p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()), "max_ms": float(a.max())}


class ServeFrontend:
    """Thread-pooled continuous-batching front end over one `DSEServer`.

    Use as a context manager (``with ServeFrontend(srv) as fe: ...``) or
    call ``start()``/``stop()`` explicitly.  One lock serializes every
    server-state mutation (submission, formation, publication); only the
    engine execution itself runs outside it.
    """

    def __init__(self, server: DSEServer,
                 cfg: Optional[FrontendConfig] = None):
        self.cfg = cfg or FrontendConfig()
        assert self.cfg.admission in ("reject", "block"), self.cfg.admission
        self.server = server
        self._lock = threading.RLock()
        self._space = threading.Condition(self._lock)   # queue-space waiters
        self._work = threading.Event()                  # submit -> former
        self._futures: Dict[int, Future] = {}
        self._meta: Dict[int, Tuple[str, float]] = {}   # rid -> (model, t0)
        # responses that land before submit() has registered the rid (cache
        # hits / door rejections resolve inside server.submit); bounded so
        # responses for rids never submitted through this front end (mixed
        # sync use) cannot accumulate
        self._early: "OrderedDict[int, DSEResponse]" = OrderedDict()
        self._latencies: Deque[float] = deque(
            maxlen=max(self.cfg.latency_window, 1))
        self._prepared: "queue.Queue[Optional[MicroBatch]]" = queue.Queue(
            maxsize=max(self.cfg.max_prepared, 1))
        self._running = False
        self._stopping = False
        self._threads: List[threading.Thread] = []
        # response observers (the online loop's hard-example harvest tap);
        # called under the front-end lock for every server response
        self._listeners: List[Callable[[DSEResponse], None]] = []
        self._listener_errors = 0
        self._last_listener_error: Optional[str] = None
        server.on_response = self._on_response

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> "ServeFrontend":
        with self._lock:
            if self._running:
                return self
            self._running, self._stopping = True, False
        self._threads = [
            threading.Thread(target=self._former_loop, name="dse-former",
                             daemon=True),
            threading.Thread(target=self._dispatch_loop,
                             name="dse-dispatcher", daemon=True),
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 120.0) -> None:
        """Stop the pipeline.  ``drain=True`` serves everything still
        queued first; ``drain=False`` rejects queued requests (REJECTED,
        "server shutting down") but still finishes already-formed batches.
        Either way every outstanding future resolves."""
        with self._lock:
            if not self._running:
                return
            self._stopping = True
            if not drain:
                self.server.reject_pending()
        self._work.set()
        for t in self._threads:
            t.join(timeout)
        with self._lock:
            self._running = False
            # defensive: no future may outlive the pipeline
            for rid, fut in list(self._futures.items()):
                model, _ = self._meta.get(rid, ("?", 0.0))
                self._resolve(fut, rid, DSEResponse(
                    rid, model, None, SOURCE_REJECTED,
                    error="front end stopped"))
            self._futures.clear()

    def __enter__(self) -> "ServeFrontend":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.stop(drain=True)

    # ---- submission --------------------------------------------------------
    def submit(self, model_name: str, net_idx: np.ndarray, lat_obj: float,
               pow_obj: float, seed: int = 0,
               timeout_s: Optional[float] = None) -> Future:
        """Non-blocking submit; returns a Future resolving to the request's
        `DSEResponse` (the future carries ``.rid``).  ``timeout_s`` sets
        the deadline (None = ``FrontendConfig.default_timeout_s``; pass
        ``float("inf")`` to force no deadline past a config default).
        With ``admission="block"`` and a full queue this call waits for
        space (backpressure); with ``admission="reject"`` it returns an
        already-resolved REJECTED future."""
        t = timeout_s if timeout_s is not None else self.cfg.default_timeout_s
        deadline = None if t is None or not math.isfinite(t) else _now() + t
        fut: Future = Future()
        with self._space:
            # checked under the lock: a lock-free read races stop(), which
            # flips _running while draining _futures
            if not self._running:
                raise RuntimeError("ServeFrontend not started (use start() "
                                   "or a with-block)")
            if self.cfg.admission == "block" and self.server.cfg.max_queue > 0:
                while (not self._stopping
                       and self.server.batcher.pending(model_name)
                       >= self.server.cfg.max_queue
                       and (deadline is None or _now() < deadline)):
                    self._space.wait(timeout=0.05)
            t0 = time.perf_counter()
            rid = self.server.submit(model_name, net_idx, lat_obj, pow_obj,
                                     seed=seed, deadline=deadline)
            early = self._early.pop(rid, None)
            if early is not None:           # cache hit / door rejection
                self._resolve(fut, rid, early, t0)
            else:
                self._futures[rid] = fut
                self._meta[rid] = (model_name, t0)
        self._work.set()
        fut.rid = rid  # type: ignore[attr-defined]
        return fut

    def submit_network(self, model_name: str, desc: Dict[str, float],
                       lat_obj: float, pow_obj: float, seed: int = 0,
                       timeout_s: Optional[float] = None) -> Future:
        from repro.core.dse_api import parse_network
        net_idx = parse_network(desc, self.server.engines[model_name].model)
        return self.submit(model_name, net_idx, lat_obj, pow_obj, seed=seed,
                           timeout_s=timeout_s)

    # ---- params hot-swap ---------------------------------------------------
    def swap(self, model_name: str, ds: "Dataset", g_params: Dict) -> int:
        """Lock-disciplined hot swap: refresh a hosted engine's
        dataset/params (``DSEServer.swap`` -> ``GANDSE.attach``, zero
        recompile) *under the front-end lock*, serialized against
        submission, batch formation, and publication; returns the number
        of invalidated cache entries.

        This is the only safe swap on a live front end: ``DSEServer.swap``
        mutates engine and cache state, so calling it directly races the
        former/dispatcher threads (repro-lint GL111 flags the pattern).
        A batch already executing when the swap lands is handled by the
        params-generation stamp — it still answers (with the old params,
        the documented in-flight semantics) but cannot re-poison the
        freshly invalidated cache."""
        with self._lock:
            return self.server.swap(model_name, ds, g_params)

    def add_response_listener(
            self, fn: Callable[[DSEResponse], None]) -> None:
        """Register an observer called for every server response (DONE,
        FAILED, and REJECTED alike) — the online loop's hard-example
        harvest tap.  Listeners run under the front-end lock, so they must
        be fast and non-blocking; a raising listener is counted
        (``metrics()["frontend"]["listener_errors"]``) and skipped rather
        than allowed to wedge the pipeline."""
        with self._lock:
            self._listeners.append(fn)

    def wait_all(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (no queued
        work, no buffered batches, no outstanding futures); returns False
        on timeout."""
        end = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                idle = (not self._futures
                        and self.server.batcher.pending() == 0
                        and self._prepared.empty())
            if idle:
                return True
            if end is not None and time.monotonic() >= end:
                return False
            time.sleep(0.005)

    # ---- pipeline threads --------------------------------------------------
    def _former_loop(self) -> None:
        srv = self.server
        while True:
            with self._space:
                batch = srv.form_batch()
                if batch is not None:
                    self._space.notify_all()   # queue space freed
            if batch is not None:
                # blocks when the overlap window is full — natural
                # backpressure from the dispatcher
                self._prepared.put(batch)
                continue
            pending = srv.batcher.pending()
            with self._lock:
                stopping = self._stopping
            if stopping and pending == 0:
                break
            if pending == 0:
                self._work.wait(timeout=0.05)
                self._work.clear()
            else:
                # everything with work is inside a retry-backoff window:
                # sleep toward the earliest expiry instead of spinning
                now = _now()
                waits = [srv._backoff_until.get(m, now) - now
                         for m in srv.batcher.models_with_work()]
                wait = min(waits) if waits else self.cfg.idle_sleep_s
                time.sleep(min(max(wait, self.cfg.idle_sleep_s), 0.05))
        self._prepared.put(None)               # dispatcher shutdown sentinel

    def _dispatch_loop(self) -> None:
        srv = self.server
        while True:
            batch = self._prepared.get()
            if batch is None:
                break
            try:
                # the overlap: engine compute runs with NO front-end lock,
                # so submissions and next-batch formation proceed under it
                results, info = srv.execute_batch(batch)
            except Exception as e:
                with self._space:
                    srv.fail_batch(batch, e)   # requeue/FAIL + arm backoff
                    self._space.notify_all()
                self._work.set()
                continue
            with self._space:
                srv.publish_batch(batch, results, info)
                self._space.notify_all()

    # ---- response plumbing -------------------------------------------------
    def _on_response(self, resp: DSEResponse) -> None:  # lint: disable=lock-discipline
        # called from DSEServer._respond — always under self._lock (every
        # server-state mutation happens inside it), so taking it again
        # here would only recurse on the RLock
        for listener in self._listeners:
            try:
                listener(resp)
            except Exception as e:
                # an observer must never take down the pipeline; the error
                # is recorded (not swallowed silently) for metrics()
                self._listener_errors += 1
                self._last_listener_error = repr(e)
        fut = self._futures.pop(resp.rid, None)
        if fut is None:
            self._early[resp.rid] = resp
            while len(self._early) > 1024:
                self._early.popitem(last=False)
            return
        self._resolve(fut, resp.rid, resp)

    def _resolve(self, fut: Future, rid: int, resp: DSEResponse,  # lint: disable=lock-discipline
                 t0: Optional[float] = None) -> None:
        # contract: only reached from submit() / _on_response(), both of
        # which already hold self._lock
        meta = self._meta.pop(rid, None)
        if t0 is None and meta is not None:
            t0 = meta[1]
        if t0 is not None:
            self._latencies.append(time.perf_counter() - t0)
        if not fut.done():
            fut.set_result(resp)

    # ---- introspection -----------------------------------------------------
    def metrics(self) -> Dict:
        """Health/metrics snapshot: the server summary (queue depths, shed
        and degraded counters, cache hit rate, backoff state) plus front
        -end submit->response latency percentiles and pipeline depth."""
        with self._lock:
            s = self.server.summary()
            s["frontend"] = {
                "running": self._running,
                "inflight": len(self._futures),
                "prepared_batches": self._prepared.qsize(),
                "admission": self.cfg.admission,
                "listeners": len(self._listeners),
                "listener_errors": self._listener_errors,
                "last_listener_error": self._last_listener_error,
                "latency": _percentiles(list(self._latencies)),
            }
            return s
