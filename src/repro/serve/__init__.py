"""Micro-batching DSE serving subsystem.

Single DSE requests -> per-model queues -> pow2-bucketed micro-batches ->
one `explore_tasks` dispatch each -> per-request `DSEResult`s, with an LRU
result cache and a multi-model registry with params hot-swap.  See
`repro.serve.server.DSEServer` for the sync event-loop semantics and
`repro.serve.frontend.ServeFrontend` for the concurrent production front
end (futures, continuous batching, admission control, deadlines, load
shedding); `repro.serve.faults` injects faults for the soak harness;
`repro.serve.online` closes the train-while-serve loop (harvest hard
tasks -> incremental train -> checkpoint -> lock-disciplined hot swap).
"""
from repro.serve.batcher import MicroBatch, MicroBatcher  # noqa: F401
from repro.serve.cache import ResultCache  # noqa: F401
from repro.serve.faults import (FaultPlan, FaultyEngine,  # noqa: F401
                                InjectedFault, corrupt_checkpoint)
from repro.serve.frontend import FrontendConfig, ServeFrontend  # noqa: F401
from repro.serve.online import (HardReplay, HardTaskBuffer,  # noqa: F401
                                OnlineConfig, OnlineLoop,
                                mine_hard_examples)
from repro.serve.request import (DSERequest, DSEResponse,  # noqa: F401
                                 SOURCE_CACHE, SOURCE_COALESCED,
                                 SOURCE_DISPATCH, SOURCE_FAILED,
                                 SOURCE_REJECTED)
from repro.serve.server import DSEServer, ServeConfig  # noqa: F401
