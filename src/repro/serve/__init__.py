"""Micro-batching DSE serving subsystem.

Single DSE requests -> per-model queues -> pow2-bucketed micro-batches ->
one `explore_tasks` dispatch each -> per-request `DSEResult`s, with an LRU
result cache and a multi-model registry with params hot-swap.  See
`repro.serve.server.DSEServer` for the full semantics.
"""
from repro.serve.batcher import MicroBatch, MicroBatcher  # noqa: F401
from repro.serve.cache import ResultCache  # noqa: F401
from repro.serve.request import (DSERequest, DSEResponse,  # noqa: F401
                                 SOURCE_CACHE, SOURCE_COALESCED,
                                 SOURCE_DISPATCH, SOURCE_FAILED)
from repro.serve.server import DSEServer, ServeConfig  # noqa: F401
