"""`DSEServer`: the micro-batching serving front-end for DSE engines.

The paper reports per-query DSE latency (Table 5); a production deployment
sees many independent in-flight queries.  This server closes the gap
between single submissions and the device-resident batched exploration
path that PR 2/3 built for *pre-formed* batches:

- ``submit`` admits one request (or parses a raw network description) into
  a per-model FIFO queue, answering straight from the LRU result cache
  when an identical query was already served, or coalescing onto an
  identical in-flight request so equal work is dispatched once;
- ``step`` pops one pow2-bucketed micro-batch and dispatches it through
  the engine's ``explore_tasks`` (the `DSEMethod` protocol) with per
  -request seeds, so every response is Selection-identical to a standalone
  ``explore`` call — batching is invisible to correctness;
- ``drain`` steps until every queue is empty and hands back the pending
  responses;
- ``register`` hosts one engine per design model, and ``swap`` hot-swaps a
  model's generator params via ``GANDSE.attach`` — params refresh without
  recompilation (the compiled G forward is cached on (space, gan_cfg)),
  with that model's cache entries invalidated.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dse_api import DSEMethod, DSEResult, parse_network
from repro.serve.batcher import MicroBatch, MicroBatcher
from repro.serve.cache import ResultCache
from repro.serve.request import (SOURCE_CACHE, SOURCE_COALESCED,
                                 SOURCE_DISPATCH, SOURCE_FAILED,
                                 DSERequest, DSEResponse)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 64          # micro-batch cap (before pow2 padding)
    cache_capacity: int = 4096   # LRU entries; <= 0 disables result caching
    pad_pow2: bool = True        # bucket batch sizes so the jit cache stays bounded
    coalesce_identical: bool = True  # identical queued requests dispatch once
    response_retention: int = 4096   # newest responses kept (rid lookup AND
                                     # undrained outbox); size >= expected
                                     # per-drain volume
    max_dispatch_attempts: int = 2   # per-request cap before a FAILED response
    use_fused: Optional[bool] = None  # Pallas fused-MLP dispatch override
                                      # pushed onto every registered engine
                                      # (None = leave the engine's own
                                      # setting / backend auto)
    n_shards: Optional[int] = None   # micro-batch shard multiple; None =
                                     # follow the active task mesh
                                     # (repro.core.shard), 1 = never shard


class DSEServer:
    """Multi-model micro-batching DSE server (single-threaded event loop:
    submissions and dispatches interleave on the caller's thread)."""

    def __init__(self, cfg: Optional[ServeConfig] = None):
        self.cfg = cfg or ServeConfig()
        self.engines: Dict[str, DSEMethod] = {}
        self.cache = ResultCache(self.cfg.cache_capacity)
        self.batcher = MicroBatcher(self.cfg.max_batch, self.cfg.pad_pow2,
                                    n_shards=self.cfg.n_shards)
        self._next_rid = 0
        # key -> rids of identical requests riding the queued one
        self._followers: Dict[Tuple, List[int]] = {}
        # bounded rid -> response map (oldest evicted past retention), so a
        # long-lived server under sustained traffic holds steady memory
        self._responses: "OrderedDict[int, DSEResponse]" = OrderedDict()
        self._outbox: List[DSEResponse] = []
        self._attempts: Dict[int, int] = {}   # rid -> failed dispatch count
        self.stats = {
            "submitted": 0, "dispatched_rows": 0, "padded_rows": 0,
            "batches": 0, "coalesced": 0, "swaps": 0, "failed": 0,
            "dispatch_s": 0.0,
        }

    # ---- registry ----------------------------------------------------------
    def register(self, engine: DSEMethod) -> DSEMethod:
        """Host ``engine`` for its design model (one engine per model name);
        re-registering a name replaces the engine and drops its cache.
        When ``ServeConfig.use_fused`` is set, the server pushes it onto
        the engine (``set_use_fused``) so every hosted engine serves with
        one consistent kernel route."""
        name = engine.model.name
        if name in self.engines:
            self.cache.invalidate_model(name)
        if self.cfg.use_fused is not None:
            setter = getattr(engine, "set_use_fused", None)
            if setter is not None:
                setter(self.cfg.use_fused)
        self.engines[name] = engine
        return engine

    def swap(self, model_name: str, ds, g_params) -> int:
        """Hot-swap a model's dataset/params via the engine's ``attach``
        (no retrain, no recompile) and invalidate its cached results;
        returns the number of invalidated entries.  Queued requests are
        served by the new params — like any refresh, in-flight work lands
        on whichever params are attached at dispatch time."""
        self.engines[model_name].attach(ds, g_params)
        self.stats["swaps"] += 1
        return self.cache.invalidate_model(model_name)

    # ---- admission ---------------------------------------------------------
    def submit(self, model_name: str, net_idx, lat_obj: float,
               pow_obj: float, seed: int = 0) -> int:
        """Admit one DSE query; returns its request id.  The response
        appears on the next ``drain``/``step`` that covers it (immediately
        for a cache hit)."""
        assert model_name in self.engines, f"no engine for '{model_name}'"
        # copy: asarray aliases an int64 caller buffer, and the request's
        # cache/coalescing key is recomputed from net_idx at dispatch — a
        # caller-side mutation must not desync it (or poison the cache)
        net_idx = np.array(net_idx, np.int64, copy=True).reshape(-1)
        # reject at the door: a malformed request must never reach (and
        # poison) a batch — and a negative index would wrap silently in
        # numpy, exploring (and caching!) the wrong network
        net_space = self.engines[model_name].model.net_space
        if net_idx.shape[0] != net_space.n_dims:
            raise ValueError(f"net_idx has {net_idx.shape[0]} dims, "
                             f"'{model_name}' expects {net_space.n_dims}")
        sizes = np.asarray(net_space.group_sizes)
        if np.any((net_idx < 0) | (net_idx >= sizes)):
            raise ValueError(f"net_idx {net_idx.tolist()} out of range for "
                             f"'{model_name}' (sizes {sizes.tolist()})")
        rid = self._next_rid
        self._next_rid += 1
        self.stats["submitted"] += 1
        req = DSERequest(rid=rid, model_name=model_name, net_idx=net_idx,
                         lat_obj=float(lat_obj), pow_obj=float(pow_obj),
                         seed=int(seed))
        key = req.key
        hit = self.cache.get(key)
        if hit is not None:
            self._respond(DSEResponse(rid, model_name, hit, SOURCE_CACHE))
            return rid
        if self.cfg.coalesce_identical and key in self._followers:
            self._followers[key].append(rid)
            self.stats["coalesced"] += 1
            return rid
        self._followers[key] = []
        self.batcher.admit(req)
        return rid

    def submit_network(self, model_name: str, desc: Dict[str, float],
                       lat_obj: float, pow_obj: float, seed: int = 0) -> int:
        """Parsing-phase front door: a raw network description is snapped
        onto the model's net space (`parse_network`) before admission."""
        net_idx = parse_network(desc, self.engines[model_name].model)
        return self.submit(model_name, net_idx, lat_obj, pow_obj, seed=seed)

    # ---- dispatch ----------------------------------------------------------
    def step(self, model_name: Optional[str] = None) -> int:
        """Dispatch one micro-batch (round-robin over models with work when
        ``model_name`` is None); returns the number of requests answered
        (0 when idle)."""
        batch = self.batcher.next_batch(model_name)
        if batch is None:
            return 0
        return self._dispatch(batch)

    def drain(self) -> List[DSEResponse]:
        """Step until every queue is empty, then hand back (and clear) all
        responses produced since the last drain, in production order."""
        while self.step() > 0:
            pass
        out, self._outbox = self._outbox, []
        return out

    def response(self, rid: int) -> Optional[DSEResponse]:
        return self._responses.get(rid)

    def _dispatch(self, batch: MicroBatch) -> int:
        engine = self.engines[batch.model_name]
        t0 = time.time()
        try:
            results = engine.explore_tasks(batch.tasks, seed=batch.seeds)
        except Exception as e:
            # dispatch failed: requeue the popped requests at the head of
            # their queue (followers stay attached) so nothing is lost —
            # except requests that keep failing, which get a FAILED
            # response instead of wedging the queue forever (a poison
            # request would otherwise starve its whole model)
            retry = []
            for req in batch.requests:
                n = self._attempts.get(req.rid, 0) + 1
                if n < self.cfg.max_dispatch_attempts:
                    self._attempts[req.rid] = n
                    retry.append(req)
                else:
                    self._attempts.pop(req.rid, None)
                    self._fail(req, batch.model_name, e)
            self.batcher.requeue_front(retry)
            raise
        self.stats["dispatch_s"] += time.time() - t0
        self.stats["batches"] += 1
        self.stats["dispatched_rows"] += batch.n_real
        self.stats["padded_rows"] += batch.padded_size - batch.n_real
        answered = 0
        for i, req in enumerate(batch.requests):   # padding rows discarded
            res: DSEResult = results[i]
            key = req.key
            self._attempts.pop(req.rid, None)
            self.cache.put(key, res)
            self._respond(DSEResponse(req.rid, batch.model_name, res,
                                      SOURCE_DISPATCH, batch.n_real))
            answered += 1
            for rid in self._followers.pop(key, ()):
                self._respond(DSEResponse(rid, batch.model_name, res,
                                          SOURCE_COALESCED, batch.n_real))
                answered += 1
        return answered

    def _fail(self, req: DSERequest, model_name: str, exc: Exception) -> None:
        self.stats["failed"] += 1
        self._respond(DSEResponse(req.rid, model_name, None,
                                  SOURCE_FAILED, error=str(exc)))
        for rid in self._followers.pop(req.key, ()):
            self.stats["failed"] += 1
            self._respond(DSEResponse(rid, model_name, None,
                                      SOURCE_FAILED, error=str(exc)))

    def _respond(self, resp: DSEResponse) -> None:
        self._responses[resp.rid] = resp
        while len(self._responses) > max(self.cfg.response_retention, 1):
            self._responses.popitem(last=False)
        self._outbox.append(resp)
        # same bound for the drain outbox: a step()/response(rid) polling
        # loop that never drains must not accumulate responses forever
        if len(self._outbox) > max(self.cfg.response_retention, 1):
            del self._outbox[0]

    # ---- introspection -----------------------------------------------------
    def summary(self) -> Dict:
        import jax

        from repro.kernels import dispatch as _dispatch

        s = dict(self.stats)
        s["pending"] = self.batcher.pending()
        s["cache"] = self.cache.stats()
        s["models"] = sorted(self.engines)
        s["mean_batch_size"] = (s["dispatched_rows"] / s["batches"]
                                if s["batches"] else 0.0)
        def engine_route(e) -> bool:
            # the route this engine's dispatches actually take: the server
            # -level flag when set, else the engine's own setting (backend
            # conjunct included — "on" off-TPU still reports False)
            flag = self.cfg.use_fused
            if flag is None:
                gc = getattr(e, "gan_cfg", None)
                flag = gc.use_fused if gc is not None \
                    else getattr(e, "use_fused", None)
            return _dispatch.kernel_route_active(flag)

        s["kernels"] = {
            "backend": jax.default_backend(),
            "fused": {name: engine_route(e)
                      for name, e in sorted(self.engines.items())},
        }
        from repro.core import shard as _shard
        mesh = _shard.get_task_mesh()
        s["sharding"] = {
            "n_shards": self.batcher._shards(),
            "mesh": dict(mesh.shape) if mesh is not None else None,
            "task_axes": _shard.task_axes(mesh),
        }
        return s
