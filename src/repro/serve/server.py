"""`DSEServer`: the micro-batching serving front-end for DSE engines.

The paper reports per-query DSE latency (Table 5); a production deployment
sees many independent in-flight queries.  This server closes the gap
between single submissions and the device-resident batched exploration
path that PR 2/3 built for *pre-formed* batches:

- ``submit`` admits one request (or parses a raw network description) into
  a per-model FIFO queue, answering straight from the LRU result cache
  when an identical query was already served, or coalescing onto an
  identical in-flight request so equal work is dispatched once; when a
  model's queue is at ``ServeConfig.max_queue`` the request is shed at the
  door with a REJECTED response carrying a retry-after hint (admission
  control: bounded queues instead of unbounded buffering);
- ``step`` sheds expired-deadline requests, pops one pow2-bucketed
  micro-batch from a model outside its retry-backoff window, and
  dispatches it through the engine's ``explore_tasks`` (the `DSEMethod`
  protocol) with per-request seeds, so every response is Selection
  -identical to a standalone ``explore`` call — batching is invisible to
  correctness;
- ``drain`` steps until every queue is empty (waiting out retry-backoff
  windows) and hands back the pending responses;
- ``register`` hosts one engine per design model, and ``swap`` hot-swaps a
  model's generator params via ``GANDSE.attach`` — params refresh without
  recompilation (the compiled G forward is cached on (space, gan_cfg)),
  with that model's cache entries invalidated.

Failure semantics: a dispatch exception requeues the batch at the head of
its queue and arms a jittered-exponential-backoff window for that model
(no immediate re-hammering of a failing engine); a request that keeps
failing past ``max_dispatch_attempts`` gets a FAILED response instead of
wedging its queue.  After ``degrade_after`` consecutive dispatch failures
the model's dispatches fall back to the sequential host-oracle route
(``explore_tasks(batched=False)`` — same Selections by the repo-wide
parity contract, just slower), with the device route re-probed every
``degrade_probe_after`` successful degraded dispatches so the model
recovers as soon as the device route heals.  Responses computed by the
fallback carry ``degraded=True``.

Threading contract: `DSEServer` itself is an event loop — submissions,
batch formation, and publication must be serialized by the caller (the
sync pump does this trivially on one thread; `repro.serve.frontend`
serializes them with one lock).  The split dispatch API exists for that
front end: ``form_batch`` / ``execute_batch`` / ``publish_batch`` /
``fail_batch``, where only ``execute_batch`` (the engine call — host
batching and device compute) may safely run *outside* the caller's lock,
overlapping with concurrent submissions and formation.
"""
from __future__ import annotations

import dataclasses
import inspect
import random
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dse_api import DSEMethod, DSEResult, parse_network
from repro.serve.batcher import MicroBatch, MicroBatcher
from repro.serve.cache import ResultCache
from repro.serve.request import (SOURCE_CACHE, SOURCE_COALESCED,
                                 SOURCE_DISPATCH, SOURCE_FAILED,
                                 SOURCE_REJECTED, DSERequest, DSEResponse)


def _now() -> float:
    """Scheduling clock (deadlines, backoff windows): monotonic so a wall
    -clock step never expires or revives a request."""
    return time.monotonic()


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 64          # micro-batch cap (before pow2 padding)
    cache_capacity: int = 4096   # LRU entries; <= 0 disables result caching
    pad_pow2: bool = True        # bucket batch sizes so the jit cache stays bounded
    coalesce_identical: bool = True  # identical queued requests dispatch once
    response_retention: int = 4096   # newest responses kept (rid lookup AND
                                     # undrained outbox); size >= expected
                                     # per-drain volume
    max_dispatch_attempts: int = 2   # per-request cap before a FAILED response
    max_queue: int = 0           # per-model queued-request bound; a submit
                                 # past it is REJECTED with a retry-after
                                 # hint (<= 0 = unbounded, the old behavior)
    retry_backoff_base: float = 0.05  # s; first retry delay after a dispatch
                                      # failure, doubling per consecutive
                                      # failure (jittered) up to the max —
                                      # replaces the old immediate retry
    retry_backoff_max: float = 2.0
    retry_jitter: float = 0.25   # uniform +-fraction applied to each delay
                                 # (0 = deterministic, for tests)
    degrade_after: int = 3       # consecutive dispatch failures before a
                                 # model's dispatches fall back to the
                                 # sequential host-oracle route (<= 0 or an
                                 # engine without the `batched=` kwarg
                                 # disables the fallback)
    degrade_probe_after: int = 4  # successful degraded dispatches between
                                  # device-route recovery probes
    use_fused: Optional[bool] = None  # Pallas fused-MLP dispatch override
                                      # pushed onto every registered engine
                                      # (None = leave the engine's own
                                      # setting / backend auto)
    n_shards: Optional[int] = None   # micro-batch shard multiple; None =
                                     # follow the active task mesh
                                     # (repro.core.shard), 1 = never shard


class DSEServer:
    """Multi-model micro-batching DSE server (single-threaded event loop:
    submissions and dispatches interleave on the caller's thread; see the
    module docstring for the concurrent front end's split-dispatch
    contract)."""

    def __init__(self, cfg: Optional[ServeConfig] = None):
        self.cfg = cfg or ServeConfig()
        self.engines: Dict[str, DSEMethod] = {}
        self.cache = ResultCache(self.cfg.cache_capacity)
        self.batcher = MicroBatcher(self.cfg.max_batch, self.cfg.pad_pow2,
                                    n_shards=self.cfg.n_shards)
        self._next_rid = 0
        # key -> rids of identical requests riding the queued one
        self._followers: Dict[Tuple, List[int]] = {}
        # bounded rid -> response map (oldest evicted past retention), so a
        # long-lived server under sustained traffic holds steady memory
        self._responses: "OrderedDict[int, DSEResponse]" = OrderedDict()
        self._outbox: List[DSEResponse] = []
        self._attempts: Dict[int, int] = {}   # rid -> failed dispatch count
        self._consec_fail: Dict[str, int] = {}    # model -> consecutive fails
        self._backoff_until: Dict[str, float] = {}  # model -> monotonic time
        self._degraded: Dict[str, Dict] = {}  # model -> {"ok": n, "since": t}
        self._supports_batched: Dict[str, bool] = {}
        # model -> params generation: bumped by every swap()/re-register.
        # Formed batches are stamped with it (see MicroBatch.params_gen)
        # so publish_batch can tell a result was computed under params the
        # swap already retired and skip the cache put (the stale-cache
        # -after-swap race; tests/test_serve_concurrency.py pins it).
        self._params_gen: Dict[str, int] = {}
        self._rng = random.Random(0x5EED)     # backoff jitter (deterministic)
        #: response hook for the concurrent front end (called synchronously
        #: inside _respond, i.e. under whatever lock the caller holds)
        self.on_response: Optional[Callable[[DSEResponse], None]] = None
        self.stats = {
            "submitted": 0, "dispatched_rows": 0, "padded_rows": 0,
            "batches": 0, "coalesced": 0, "swaps": 0, "failed": 0,
            "dispatch_s": 0.0, "dispatch_attempts": 0, "retried": 0,
            "rejected": 0, "rejected_queue": 0, "rejected_deadline": 0,
            "degraded_entered": 0, "degraded_recovered": 0,
            "degraded_batches": 0, "probe_failures": 0,
            "stale_cache_skips": 0,
        }

    # ---- registry ----------------------------------------------------------
    def register(self, engine: DSEMethod) -> DSEMethod:
        """Host ``engine`` for its design model (one engine per model name);
        re-registering a name replaces the engine and drops its cache.
        When ``ServeConfig.use_fused`` is set, the server pushes it onto
        the engine (``set_use_fused``) so every hosted engine serves with
        one consistent kernel route."""
        name = engine.model.name
        if name in self.engines:
            # replacing an engine is a params change like any swap: retire
            # the cache entries and the generation in-flight batches carry
            self._params_gen[name] = self._params_gen.get(name, 0) + 1
            self.cache.invalidate_model(name)
        if self.cfg.use_fused is not None:
            setter = getattr(engine, "set_use_fused", None)
            if setter is not None:
                setter(self.cfg.use_fused)
        self.engines[name] = engine
        try:
            sig = inspect.signature(engine.explore_tasks)
            self._supports_batched[name] = "batched" in sig.parameters
        except (TypeError, ValueError):
            self._supports_batched[name] = False
        return engine

    def swap(self, model_name: str, ds, g_params) -> int:
        """Hot-swap a model's dataset/params via the engine's ``attach``
        (no retrain, no recompile) and invalidate its cached results;
        returns the number of invalidated entries.  Queued requests are
        served by the new params — like any refresh, in-flight work lands
        on whichever params are attached at dispatch time.  Bumps the
        model's params generation, so a batch executing across the swap
        still responds but cannot re-cache its old-params result.

        On a server wrapped by a live `ServeFrontend`, call
        ``ServeFrontend.swap`` instead: this method mutates engine and
        cache state and must run under the front-end lock (repro-lint
        GL111 flags direct ``.server.swap(...)`` calls)."""
        self.engines[model_name].attach(ds, g_params)
        self.stats["swaps"] += 1
        self._params_gen[model_name] = self._params_gen.get(model_name, 0) + 1
        return self.cache.invalidate_model(model_name)

    def params_generation(self, model_name: str) -> int:
        """Monotonic per-model params version (0 until the first swap)."""
        return self._params_gen.get(model_name, 0)

    # ---- admission ---------------------------------------------------------
    def submit(self, model_name: str, net_idx, lat_obj: float,
               pow_obj: float, seed: int = 0,
               deadline: Optional[float] = None) -> int:
        """Admit one DSE query; returns its request id.  The response
        appears on the next ``drain``/``step`` that covers it (immediately
        for a cache hit, a queue-bound rejection, or an already-expired
        deadline).  ``deadline`` is a ``time.monotonic()`` instant: the
        request is shed (REJECTED, with a retry-after hint) if it is still
        queued when the deadline passes."""
        assert model_name in self.engines, f"no engine for '{model_name}'"
        # copy: asarray aliases an int64 caller buffer, and the request's
        # cache/coalescing key is recomputed from net_idx at dispatch — a
        # caller-side mutation must not desync it (or poison the cache)
        net_idx = np.array(net_idx, np.int64, copy=True).reshape(-1)
        # reject at the door: a malformed request must never reach (and
        # poison) a batch — and a negative index would wrap silently in
        # numpy, exploring (and caching!) the wrong network
        net_space = self.engines[model_name].model.net_space
        if net_idx.shape[0] != net_space.n_dims:
            raise ValueError(f"net_idx has {net_idx.shape[0]} dims, "
                             f"'{model_name}' expects {net_space.n_dims}")
        sizes = np.asarray(net_space.group_sizes)
        if np.any((net_idx < 0) | (net_idx >= sizes)):
            raise ValueError(f"net_idx {net_idx.tolist()} out of range for "
                             f"'{model_name}' (sizes {sizes.tolist()})")
        rid = self._next_rid
        self._next_rid += 1
        self.stats["submitted"] += 1
        req = DSERequest(rid=rid, model_name=model_name, net_idx=net_idx,
                         lat_obj=float(lat_obj), pow_obj=float(pow_obj),
                         seed=int(seed), deadline=deadline)
        key = req.key
        hit = self.cache.get(key)
        if hit is not None:
            self._respond(DSEResponse(rid, model_name, hit, SOURCE_CACHE,
                                      net_idx=net_idx, seed=req.seed))
            return rid
        if self.cfg.coalesce_identical and key in self._followers:
            self._followers[key].append(rid)
            self.stats["coalesced"] += 1
            return rid
        if (self.cfg.max_queue > 0
                and self.batcher.pending(model_name) >= self.cfg.max_queue):
            self.stats["rejected_queue"] += 1
            self._reject(rid, model_name,
                         f"queue full ({self.cfg.max_queue} queued)",
                         self._retry_after(model_name))
            return rid
        if req.expired(_now()):
            self.stats["rejected_deadline"] += 1
            self._reject(rid, model_name, "deadline expired at admission",
                         self._retry_after(model_name))
            return rid
        self._followers[key] = []
        self.batcher.admit(req)
        return rid

    def submit_network(self, model_name: str, desc: Dict[str, float],
                       lat_obj: float, pow_obj: float, seed: int = 0,
                       deadline: Optional[float] = None) -> int:
        """Parsing-phase front door: a raw network description is snapped
        onto the model's net space (`parse_network`) before admission."""
        net_idx = parse_network(desc, self.engines[model_name].model)
        return self.submit(model_name, net_idx, lat_obj, pow_obj, seed=seed,
                           deadline=deadline)

    # ---- load shedding -----------------------------------------------------
    def shed_expired(self, now: Optional[float] = None) -> int:
        """Shed every queued request whose deadline has passed (REJECTED
        with a retry-after hint, followers included) *before* it can occupy
        a dispatch slot; returns the number of responses produced."""
        now = _now() if now is None else now
        shed = self.batcher.shed(lambda r: r.expired(now))
        n = 0
        for req in shed:
            self._attempts.pop(req.rid, None)
            hint = self._retry_after(req.model_name)
            self.stats["rejected_deadline"] += 1
            self._reject(req.rid, req.model_name,
                         "deadline expired before dispatch", hint)
            n += 1
            for rid in self._followers.pop(req.key, ()):
                self.stats["rejected_deadline"] += 1
                self._reject(rid, req.model_name,
                             "deadline expired before dispatch", hint)
                n += 1
        return n

    def reject_pending(self, error: str = "server shutting down") -> int:
        """Shed *every* queued request (followers included) with a REJECTED
        response — the shutdown path's every-request-terminates guarantee."""
        shed = self.batcher.shed(lambda r: True)
        n = 0
        for req in shed:
            self._attempts.pop(req.rid, None)
            self._reject(req.rid, req.model_name, error, None)
            n += 1
            for rid in self._followers.pop(req.key, ()):
                self._reject(rid, req.model_name, error, None)
                n += 1
        return n

    def _reject(self, rid: int, model_name: str, error: str,
                retry_after: Optional[float]) -> None:
        self.stats["rejected"] += 1
        self._respond(DSEResponse(rid, model_name, None, SOURCE_REJECTED,
                                  error=error, retry_after=retry_after))

    def _retry_after(self, model_name: str) -> float:
        """Resubmit-after hint: the queue's estimated drain time at the
        observed dispatch throughput (rough floor-guess before any
        throughput history exists)."""
        pending = self.batcher.pending(model_name)
        if self.stats["dispatch_s"] > 0 and self.stats["dispatched_rows"] > 0:
            rate = self.stats["dispatched_rows"] / self.stats["dispatch_s"]
            est = (pending + 1) / max(rate, 1e-9)
        else:
            est = 0.05 * (pending + 1)
        return float(min(max(est, self.cfg.retry_backoff_base, 1e-3), 60.0))

    # ---- dispatch ----------------------------------------------------------
    def form_batch(self, model_name: Optional[str] = None,
                   now: Optional[float] = None) -> Optional[MicroBatch]:
        """Shed expired requests, then pop the next dispatchable micro
        -batch: round-robin over models with work that are outside their
        retry-backoff window when ``model_name`` is None; a targeted pop
        bypasses the backoff window (explicit caller intent) and does not
        rotate the round-robin order.  Returns None when nothing is ready
        (idle, or every model with work is backing off)."""
        now = _now() if now is None else now
        self.shed_expired(now)
        return self._pop_ready(model_name, now)

    def _pop_ready(self, model_name: Optional[str],
                   now: float) -> Optional[MicroBatch]:
        if model_name is not None:
            return self._stamp(self.batcher.next_batch(model_name))
        for name in self.batcher.models_with_work():
            if now >= self._backoff_until.get(name, 0.0):
                return self._stamp(self.batcher.next_batch(name, rotate=True))
        return None

    def _stamp(self, batch: Optional[MicroBatch]) -> Optional[MicroBatch]:
        """Stamp a formed batch with its model's current params generation
        (a requeued-then-reformed batch gets a fresh stamp)."""
        if batch is not None:
            batch.params_gen = self._params_gen.get(batch.model_name, 0)
        return batch

    def step(self, model_name: Optional[str] = None) -> int:
        """Shed expired requests and dispatch one micro-batch (round-robin
        over models with work and outside their backoff window when
        ``model_name`` is None); returns the number of requests answered —
        shed rejections included — (0 when idle or backing off)."""
        now = _now()
        answered = self.shed_expired(now)
        batch = self._pop_ready(model_name, now)
        if batch is None:
            return answered
        return answered + self._dispatch(batch)

    def drain(self) -> List[DSEResponse]:
        """Step until every queue is empty — sleeping out retry-backoff
        windows when every model with work is inside one — then hand back
        (and clear) all responses produced since the last drain, in
        production order."""
        while True:
            if self.step() > 0:
                continue
            if self.batcher.pending() == 0:
                break
            # every model with work is inside its backoff window: wait out
            # the earliest one instead of spinning
            now = _now()
            waits = [self._backoff_until.get(m, now) - now
                     for m in self.batcher.models_with_work()]
            if waits:
                time.sleep(min(max(min(waits), 0.0),
                               self.cfg.retry_backoff_max) + 1e-4)
        out, self._outbox = self._outbox, []
        return out

    def response(self, rid: int) -> Optional[DSEResponse]:
        return self._responses.get(rid)

    def _dispatch(self, batch: MicroBatch) -> int:
        """Synchronous execute + publish (the event-loop pump).  The
        exception policy here is the original one: a failed dispatch
        requeues/fails its requests (with backoff armed) and then
        re-raises to the caller — the concurrent front end composes
        execute/fail/publish itself and swallows instead."""
        try:
            results, info = self.execute_batch(batch)
        except Exception as e:
            self.fail_batch(batch, e)
            raise
        return self.publish_batch(batch, results, info)

    def execute_batch(self, batch: MicroBatch):
        """Run the engine for one formed micro-batch and return
        ``(results, info)``.  No shared serving state is mutated (route
        choice reads a snapshot of the degraded table), so the concurrent
        front end runs this *outside* its lock — device compute overlaps
        with admission and the next batch's formation.  Raises whatever
        the engine raises (route fallback exhausted): pair with
        ``fail_batch``."""
        engine = self.engines[batch.model_name]
        deg = self._degraded.get(batch.model_name)
        info = {"degraded": False, "probe": None, "elapsed": 0.0}
        t0 = time.perf_counter()
        if deg is None:
            results = engine.explore_tasks(batch.tasks, seed=batch.seeds)
        elif deg["ok"] >= max(self.cfg.degrade_probe_after, 1):
            # recovery probe: try the device route again; if it is still
            # failing, fall back to the host route for this batch too
            try:
                results = engine.explore_tasks(batch.tasks, seed=batch.seeds)
                info["probe"] = "ok"
            except Exception as e:
                info["probe"] = "failed"
                info["probe_error"] = repr(e)
                info["degraded"] = True
                results = self._host_route(engine, batch)
        else:
            info["degraded"] = True
            results = self._host_route(engine, batch)
        info["elapsed"] = time.perf_counter() - t0
        return results, info

    def _host_route(self, engine: DSEMethod, batch: MicroBatch):
        """The graceful-degradation route: the sequential host-oracle loop
        (`explore_tasks(batched=False)` — Selection-identical by the repo
        -wide parity contract).  Engines without the kwarg just retry the
        only route they have."""
        if self._supports_batched.get(batch.model_name, False):
            return engine.explore_tasks(batch.tasks, seed=batch.seeds,
                                        batched=False)
        return engine.explore_tasks(batch.tasks, seed=batch.seeds)

    def publish_batch(self, batch: MicroBatch, results: List[DSEResult],
                      info: Dict) -> int:
        """Publish one executed batch: cache, respond (followers included),
        clear failure bookkeeping, and apply the degraded-route state
        transition recorded by ``execute_batch``.  Mutates shared serving
        state: the front end calls it under its lock.

        When the model's params generation advanced while the batch was
        executing (a swap landed between the lock-free execute and this
        publish), the requests are still answered — in-flight work lands
        on whichever params were attached at dispatch time — but the
        results are NOT cached: the swap already invalidated the model's
        entries, and re-inserting a Selection computed under the retired
        params would serve a stale result forever."""
        name = batch.model_name
        stale = batch.params_gen != self._params_gen.get(name, 0)
        if stale:
            self.stats["stale_cache_skips"] += 1
        self.stats["dispatch_attempts"] += 1
        self.stats["dispatch_s"] += info["elapsed"]
        self.stats["batches"] += 1
        self.stats["dispatched_rows"] += batch.n_real
        self.stats["padded_rows"] += batch.padded_size - batch.n_real
        self._consec_fail.pop(name, None)
        self._backoff_until.pop(name, None)
        deg = self._degraded.get(name)
        if deg is not None:
            if info["probe"] == "ok":       # device route healed
                self._degraded.pop(name)
                self.stats["degraded_recovered"] += 1
            elif info["probe"] == "failed":  # still down; restart probe clock
                deg["ok"] = 0
                self.stats["probe_failures"] += 1
                self.stats["degraded_batches"] += 1
            else:
                deg["ok"] += 1
                self.stats["degraded_batches"] += 1
        answered = 0
        for i, req in enumerate(batch.requests):   # padding rows discarded
            res: DSEResult = results[i]
            key = req.key
            self._attempts.pop(req.rid, None)
            if not stale:
                self.cache.put(key, res)
            self._respond(DSEResponse(req.rid, name, res, SOURCE_DISPATCH,
                                      batch.n_real,
                                      degraded=info["degraded"],
                                      net_idx=req.net_idx, seed=req.seed))
            answered += 1
            for rid in self._followers.pop(key, ()):
                # followers are key-identical to the leader, so the
                # leader's (net_idx, seed) is theirs too
                self._respond(DSEResponse(rid, name, res, SOURCE_COALESCED,
                                          batch.n_real,
                                          degraded=info["degraded"],
                                          net_idx=req.net_idx, seed=req.seed))
                answered += 1
        return answered

    def fail_batch(self, batch: MicroBatch, exc: Exception,
                   now: Optional[float] = None) -> None:
        """Record one failed dispatch: requeue the popped requests at the
        head of their queue (followers stay attached) so nothing is lost —
        except requests past ``max_dispatch_attempts``, which get a FAILED
        response instead of wedging the queue forever.  Arms the model's
        jittered-exponential retry-backoff window and, past
        ``degrade_after`` consecutive failures, flips the model onto the
        degraded host route (backoff skipped: the fallback route is
        presumed healthy and should run immediately)."""
        now = _now() if now is None else now
        name = batch.model_name
        self.stats["dispatch_attempts"] += 1
        k = self._consec_fail.get(name, 0) + 1
        self._consec_fail[name] = k
        entered = False
        if (self.cfg.degrade_after > 0 and k >= self.cfg.degrade_after
                and name not in self._degraded
                and self._supports_batched.get(name, False)):
            self._degraded[name] = {"ok": 0, "since": now}
            self.stats["degraded_entered"] += 1
            entered = True
        self._backoff_until[name] = now + \
            (0.0 if entered else self._backoff_delay(k))
        retry = []
        for req in batch.requests:
            n = self._attempts.get(req.rid, 0) + 1
            if n < self.cfg.max_dispatch_attempts:
                self._attempts[req.rid] = n
                retry.append(req)
            else:
                self._attempts.pop(req.rid, None)
                self._fail(req, name, exc)
        self.stats["retried"] += len(retry)
        self.batcher.requeue_front(retry)

    def _backoff_delay(self, k: int) -> float:
        """Jittered exponential backoff: base * 2^(k-1) capped at the max,
        +-retry_jitter fraction of uniform noise (desynchronizes retry
        storms across models/processes)."""
        base = max(self.cfg.retry_backoff_base, 0.0)
        delay = min(base * (2.0 ** max(k - 1, 0)), self.cfg.retry_backoff_max)
        j = min(max(self.cfg.retry_jitter, 0.0), 1.0)
        if j > 0.0:
            delay *= 1.0 + j * (2.0 * self._rng.random() - 1.0)
        return max(delay, 0.0)

    def _fail(self, req: DSERequest, model_name: str, exc: Exception) -> None:
        self.stats["failed"] += 1
        self._respond(DSEResponse(req.rid, model_name, None,
                                  SOURCE_FAILED, error=str(exc)))
        for rid in self._followers.pop(req.key, ()):
            self.stats["failed"] += 1
            self._respond(DSEResponse(rid, model_name, None,
                                      SOURCE_FAILED, error=str(exc)))

    def _respond(self, resp: DSEResponse) -> None:
        self._responses[resp.rid] = resp
        while len(self._responses) > max(self.cfg.response_retention, 1):
            self._responses.popitem(last=False)
        self._outbox.append(resp)
        # same bound for the drain outbox: a step()/response(rid) polling
        # loop that never drains must not accumulate responses forever
        if len(self._outbox) > max(self.cfg.response_retention, 1):
            del self._outbox[0]
        if self.on_response is not None:
            self.on_response(resp)

    # ---- introspection -----------------------------------------------------
    def summary(self) -> Dict:
        import jax

        from repro.kernels import dispatch as _dispatch

        s = dict(self.stats)
        s["pending"] = self.batcher.pending()
        s["cache"] = self.cache.stats()
        s["models"] = sorted(self.engines)
        s["mean_batch_size"] = (s["dispatched_rows"] / s["batches"]
                                if s["batches"] else 0.0)
        now = _now()
        s["backoff"] = {m: round(t - now, 4)
                        for m, t in self._backoff_until.items() if t > now}
        s["degraded"] = sorted(self._degraded)
        s["params_generation"] = dict(self._params_gen)
        s["inflight_attempts"] = dict(self._attempts)
        def engine_route(e) -> bool:
            # the route this engine's dispatches actually take: the server
            # -level flag when set, else the engine's own setting (backend
            # conjunct included — "on" off-TPU still reports False)
            flag = self.cfg.use_fused
            if flag is None:
                gc = getattr(e, "gan_cfg", None)
                flag = gc.use_fused if gc is not None \
                    else getattr(e, "use_fused", None)
            return _dispatch.kernel_route_active(flag)

        s["kernels"] = {
            "backend": jax.default_backend(),
            "fused": {name: engine_route(e)
                      for name, e in sorted(self.engines.items())},
        }
        from repro.core import shard as _shard
        mesh = _shard.get_task_mesh()
        s["sharding"] = {
            "n_shards": self.batcher._shards(),
            "mesh": dict(mesh.shape) if mesh is not None else None,
            "task_axes": _shard.task_axes(mesh),
        }
        return s
