"""LRU result cache for served DSE queries.

Keyed on ``(model, net_idx, lat_obj, pow_obj, seed)`` — exactly the inputs
that determine a Selection under the batched-vs-sequential parity contract
(per-task noise keys depend only on the request's own seed, never on batch
placement), so a hit is indistinguishable from a recompute.  A hot-swap of
an engine's params (`DSEServer.swap`) invalidates that model's entries:
the key does not carry a params version, the swap does.

Thread safety: every operation holds one internal lock, so the concurrent
front end (`repro.serve.frontend`) can hit the cache from submitter
threads while the dispatcher publishes — get/put/invalidate interleave
atomically and the LRU order, stat counters, and capacity bound stay
consistent (pinned by tests/test_serve_concurrency.py).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from repro.core.dse_api import DSEResult


class ResultCache:
    """Bounded LRU: get/put are O(1); capacity <= 0 disables caching."""

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._d: "OrderedDict[Tuple, DSEResult]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # model -> invalidation generation: how many times this model's
        # entries were dropped (one bump per params swap/re-register) —
        # the observable the online-loop smoke pins a hot swap by
        self.invalidations: Dict[str, int] = {}

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def get(self, key: Tuple) -> Optional[DSEResult]:
        if self.capacity <= 0:
            return None
        with self._lock:
            hit = self._d.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return hit

    def put(self, key: Tuple, result: DSEResult) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._d[key] = result
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self.evictions += 1

    def invalidate_model(self, model_name: str) -> int:
        """Drop every entry of one model (key[0] is the model name); returns
        how many were dropped.  Called on params hot-swap."""
        with self._lock:
            stale = [k for k in self._d if k[0] == model_name]
            for k in stale:
                del self._d[k]
            self.invalidations[model_name] = \
                self.invalidations.get(model_name, 0) + 1
            return len(stale)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "invalidations": dict(self.invalidations)}
