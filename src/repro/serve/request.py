"""Request/response records for the DSE serving subsystem.

One `DSERequest` is one user query from the paper's exploration phase: a
parsed network (net-space indices), the two objectives `metric <= x`, and
the noise seed that makes the query reproducible.  The server answers with
a `DSEResponse` wrapping the engine's `DSEResult` plus serving metadata
(which micro-batch carried it, whether it was a cache hit or coalesced
onto an identical in-flight request, whether the degraded host route
computed it).

Terminal states — every admitted request reaches exactly one:

- ``dispatch`` / ``cache`` / ``coalesced``: answered with a result;
- ``failed``: the engine kept raising past the retry cap (``error`` holds
  the last exception's message) — the work was attempted and lost;
- ``rejected``: admission control shed the request *before* dispatch
  (queue full, deadline expired, or server shutdown) — the work was never
  attempted, and ``retry_after`` hints when resubmission is likely to be
  admitted.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.dse_api import DSEResult, cache_key
from repro.dataset.generator import DSETask

#: how a response was produced
SOURCE_DISPATCH = "dispatch"     # computed by this micro-batch
SOURCE_CACHE = "cache"           # LRU hit from an earlier dispatch
SOURCE_COALESCED = "coalesced"   # rode an identical in-flight request
SOURCE_FAILED = "failed"         # dispatch kept failing; gave up (see error)
SOURCE_REJECTED = "rejected"     # shed before dispatch (queue bound, expired
                                 # deadline, or shutdown); see retry_after


@dataclasses.dataclass(frozen=True)
class DSERequest:
    """One admitted DSE query."""

    rid: int                     # server-assigned, unique per server
    model_name: str              # which registered engine serves it
    net_idx: np.ndarray          # (n_net_dims,) parsed network indices
    lat_obj: float               # latency objective, seconds
    pow_obj: float               # power objective, watts
    seed: int = 0                # per-request noise seed
    deadline: Optional[float] = None  # time.monotonic() expiry; expired
                                      # requests are shed at batch formation
                                      # (best effort: a request already in a
                                      # formed batch is served late instead)

    @property
    def key(self) -> Tuple:
        """Result-cache identity (see `repro.core.dse_api.cache_key`).
        The deadline is serving metadata, not task identity: two requests
        for the same work coalesce regardless of their deadlines."""
        return cache_key(self.model_name, self.net_idx, self.lat_obj,
                         self.pow_obj, self.seed)

    def as_task(self) -> DSETask:
        """This request as a 1-row task batch."""
        return DSETask.single(self.net_idx, self.lat_obj, self.pow_obj)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline


@dataclasses.dataclass
class DSEResponse:
    """The server's answer to one request.  ``result`` is None only for
    SOURCE_FAILED (the engine kept raising past the retry cap; ``error``
    carries the last exception's message) and SOURCE_REJECTED (admission
    control shed the request before dispatch; ``retry_after`` hints the
    resubmission delay in seconds) responses."""

    rid: int
    model_name: str
    result: Optional[DSEResult]
    source: str = SOURCE_DISPATCH
    batch_size: int = 1          # real (unpadded) rows in the carrying batch
    error: Optional[str] = None
    retry_after: Optional[float] = None  # REJECTED only: resubmit-after hint, s
    degraded: bool = False       # computed by the sequential host-oracle
                                 # fallback route (device route was failing)
    # task identity of answered responses (None on FAILED/REJECTED): with
    # the result's own objectives these reconstruct the request's cache
    # key, which is how the online loop (`repro.serve.online`) harvests
    # unsatisfied responses as deduplicated hard training examples
    net_idx: Optional[np.ndarray] = None
    seed: Optional[int] = None

    @property
    def cached(self) -> bool:
        return self.source == SOURCE_CACHE

    @property
    def rejected(self) -> bool:
        return self.source == SOURCE_REJECTED

    @property
    def ok(self) -> bool:
        return self.result is not None
