"""Request/response records for the DSE serving subsystem.

One `DSERequest` is one user query from the paper's exploration phase: a
parsed network (net-space indices), the two objectives `metric <= x`, and
the noise seed that makes the query reproducible.  The server answers with
a `DSEResponse` wrapping the engine's `DSEResult` plus serving metadata
(which micro-batch carried it, whether it was a cache hit or coalesced
onto an identical in-flight request).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.dse_api import DSEResult, cache_key
from repro.dataset.generator import DSETask

#: how a response was produced
SOURCE_DISPATCH = "dispatch"     # computed by this micro-batch
SOURCE_CACHE = "cache"           # LRU hit from an earlier dispatch
SOURCE_COALESCED = "coalesced"   # rode an identical in-flight request
SOURCE_FAILED = "failed"         # dispatch kept failing; gave up (see error)


@dataclasses.dataclass(frozen=True)
class DSERequest:
    """One admitted DSE query."""

    rid: int                     # server-assigned, unique per server
    model_name: str              # which registered engine serves it
    net_idx: np.ndarray          # (n_net_dims,) parsed network indices
    lat_obj: float               # latency objective, seconds
    pow_obj: float               # power objective, watts
    seed: int = 0                # per-request noise seed

    @property
    def key(self) -> Tuple:
        """Result-cache identity (see `repro.core.dse_api.cache_key`)."""
        return cache_key(self.model_name, self.net_idx, self.lat_obj,
                         self.pow_obj, self.seed)

    def as_task(self) -> DSETask:
        """This request as a 1-row task batch."""
        return DSETask.single(self.net_idx, self.lat_obj, self.pow_obj)


@dataclasses.dataclass
class DSEResponse:
    """The server's answer to one request.  ``result`` is None only for
    SOURCE_FAILED responses (the engine kept raising past the retry cap);
    ``error`` then carries the last exception's message."""

    rid: int
    model_name: str
    result: Optional[DSEResult]
    source: str = SOURCE_DISPATCH
    batch_size: int = 1          # real (unpadded) rows in the carrying batch
    error: Optional[str] = None

    @property
    def cached(self) -> bool:
        return self.source == SOURCE_CACHE

    @property
    def ok(self) -> bool:
        return self.result is not None
