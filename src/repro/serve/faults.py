"""Fault injection for the serving stack: deterministic chaos for the
soak harness.

`FaultyEngine` wraps any `DSEMethod` engine and injects, per dispatch:

- **exceptions** (`InjectedFault`): a deterministic burst window
  (``burst_start``/``burst_len``, counted in device-route dispatches) plus
  an optional seeded random rate — with ``device_route_only=True``
  (default) the sequential host route (``batched=False``) is immune, so
  the server's degraded-route fallback genuinely recovers;
- **latency spikes**: seeded-random ``time.sleep`` stalls, exercising
  deadline shedding and queue backpressure without breaking correctness;
- the wrapper is otherwise transparent (explore/train/attach/set_use_fused
  pass through), so Selections are identical to the bare engine whenever a
  dispatch survives — the soak harness pins fault-run responses against
  standalone ``explore_tasks`` results.

`corrupt_checkpoint` flips bytes inside a saved checkpoint's payload so
`CheckpointManager.restore`/`verify` must raise
`CheckpointCorruptionError` — the corrupted-params-on-swap scenario: a
fault-injected retrain loop saves params, the file is damaged, and the
serving tier must detect it at swap time and keep the last good params
instead of attaching garbage.
"""
from __future__ import annotations

import dataclasses
import os
import random
import time
from typing import Optional

from repro.core.dse_api import DSEMethod


class InjectedFault(RuntimeError):
    """An exception injected by a `FaultPlan` (never a real engine error)."""


@dataclasses.dataclass
class FaultPlan:
    """What to inject.  All randomness is seeded: two runs of the same plan
    against the same traffic inject identically."""

    seed: int = 0
    #: deterministic failure window: device-route dispatches with index in
    #: [burst_start, burst_start + burst_len) raise InjectedFault (indices
    #: count only fault-eligible dispatches, so the window is route-stable)
    burst_start: int = 0
    burst_len: int = 0
    #: additional seeded-random failures, P(raise) per eligible dispatch
    error_rate: float = 0.0
    #: stop injecting errors after this many total (None = unlimited) —
    #: guarantees a finite fault window so recovery can be asserted
    max_errors: Optional[int] = None
    #: inject errors only on the device (batched) route; the sequential
    #: host fallback stays healthy — models the common real failure
    #: (compiler/OOM/accelerator flake) where the host path survives
    device_route_only: bool = True
    #: seeded-random latency spikes: P(spike) per dispatch, spike duration
    spike_rate: float = 0.0
    spike_s: float = 0.02


class FaultyEngine:
    """`DSEMethod` wrapper that executes a `FaultPlan` at dispatch time."""

    def __init__(self, engine: DSEMethod, plan: FaultPlan):
        self._inner = engine
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self.model = engine.model
        self.method_name = getattr(engine, "method_name", "faulty")
        self.injected_errors = 0
        self.injected_spikes = 0
        self.dispatches = 0          # all explore_tasks calls
        self.eligible_dispatches = 0  # calls the plan could fail

    # the serving layer reads gan_cfg/use_fused for its kernel-route report
    @property
    def gan_cfg(self):
        return getattr(self._inner, "gan_cfg", None)

    def set_use_fused(self, use_fused):
        setter = getattr(self._inner, "set_use_fused", None)
        if setter is not None:
            setter(use_fused)
        return self

    def train(self, *a, **kw):
        return self._inner.train(*a, **kw)

    def attach(self, ds, g_params):
        return self._inner.attach(ds, g_params)

    def explore(self, net_idx, lat_obj, pow_obj, seed: int = 0):
        return self._inner.explore(net_idx, lat_obj, pow_obj, seed=seed)

    def _maybe_fail(self, device_route: bool) -> None:
        p = self.plan
        if p.device_route_only and not device_route:
            return
        i = self.eligible_dispatches
        self.eligible_dispatches += 1
        if p.max_errors is not None and self.injected_errors >= p.max_errors:
            return
        in_burst = p.burst_len > 0 and \
            p.burst_start <= i < p.burst_start + p.burst_len
        if in_burst or (p.error_rate > 0
                        and self._rng.random() < p.error_rate):
            self.injected_errors += 1
            raise InjectedFault(
                f"injected dispatch fault #{self.injected_errors} "
                f"(eligible dispatch {i})")

    def explore_tasks(self, tasks, seed=0, batched=None):
        self.dispatches += 1
        p = self.plan
        if p.spike_rate > 0 and self._rng.random() < p.spike_rate:
            self.injected_spikes += 1
            time.sleep(p.spike_s)
        # batched=False is the host route; None/True take the device route
        # whenever the model supports it (the server's degraded fallback
        # passes False explicitly)
        self._maybe_fail(device_route=batched is not False)
        return self._inner.explore_tasks(tasks, seed=seed, batched=batched)

    def fault_stats(self) -> dict:
        return {"dispatches": self.dispatches,
                "eligible_dispatches": self.eligible_dispatches,
                "injected_errors": self.injected_errors,
                "injected_spikes": self.injected_spikes}


def corrupt_checkpoint(step_dir: str, seed: int = 0, n_bytes: int = 8,
                       host_index: int = 0) -> str:
    """Flip ``n_bytes`` random payload bytes of a saved checkpoint step (in
    the host npz, past the zip header so the file still opens) and return
    the damaged path.  `CheckpointManager.verify`/`restore` must raise
    `CheckpointCorruptionError` on it."""
    path = os.path.join(step_dir, f"host_{host_index}.npz")
    rng = random.Random(seed)
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        assert size > 256, f"checkpoint payload too small to corrupt: {size}"
        for _ in range(n_bytes):
            pos = rng.randrange(128, size - 64)
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0xFF]))
    return path
