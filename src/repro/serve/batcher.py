"""Micro-batch coalescing: many independent in-flight requests -> few
pow2-bucketed `DSETask` dispatches.

The batched exploration path (`GANDSE.explore_batch` and the baselines'
device routes) compiles one program per (batch size, C_pad) pair, so the
batcher reuses the `C_pad` bucketing idea on the batch axis: a micro-batch
of m requests is padded to the next power of two by repeating its last row
(padding rows are computed and discarded — every task lane is vmapped
-independent, so they cannot perturb real rows), keeping the jit cache at
<= log2(max_batch) batch-size entries no matter how ragged the arrival
pattern is.

Per-request seeds ride along as a (T,) array (`task_keys` array form), so
a request's Selection never depends on which micro-batch it landed in or
at which position.

Under an active task mesh the padded size is additionally a multiple of
the shard count — ``n_shards * pow2_bucket(ceil(m / n_shards))`` — so one
sharded dispatch serves the whole micro-batch with every device lane full
(``n_shards=None`` reads ``shard.active_n_shards()`` at formation time;
1 shard reproduces the old sizing exactly).

Thread safety: every queue operation holds one internal lock, so the
concurrent front end can admit from submitter threads while the former
thread pops micro-batches — admit/next_batch/requeue/shed interleave
atomically and no request is ever lost or double-popped (pinned by
tests/test_serve_concurrency.py).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import shard
from repro.core.explorer import pow2_bucket
from repro.dataset.generator import DSETask
from repro.serve.request import DSERequest


@dataclasses.dataclass
class MicroBatch:
    """One dispatchable unit: the real requests plus the padded task batch.

    ``tasks``/``seeds`` carry ``padded_size`` rows; only the first
    ``len(requests)`` are real, the rest repeat the last real row and are
    dropped after dispatch.
    """

    model_name: str
    requests: List[DSERequest]
    tasks: DSETask
    seeds: np.ndarray            # (padded_size,) int64 per-row noise seeds
    #: per-model params generation the batch was formed under (stamped by
    #: `DSEServer._pop_ready`).  `publish_batch` compares it against the
    #: live counter: a swap landing between the lock-free execute and the
    #: publish invalidated the model's cache entries, so a mismatched
    #: batch still responds but must not re-cache its (old-params) results.
    params_gen: int = 0

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def padded_size(self) -> int:
        return len(self.tasks)


class MicroBatcher:
    """Per-model FIFO admission queues + micro-batch formation."""

    def __init__(self, max_batch: int = 64, pad_pow2: bool = True,
                 n_shards: Optional[int] = None):
        assert max_batch >= 1
        self.max_batch = int(max_batch)
        self.pad_pow2 = bool(pad_pow2)
        #: None = follow the active task mesh (read per batch formation, so
        #: installing a mesh mid-serve takes effect on the next dispatch)
        self.n_shards = n_shards if n_shards is None else int(n_shards)
        self._queues: "OrderedDict[str, Deque[DSERequest]]" = OrderedDict()
        self._lock = threading.RLock()

    def _shards(self) -> int:
        k = self.n_shards if self.n_shards is not None \
            else shard.active_n_shards()
        return max(1, int(k))

    def admit(self, req: DSERequest) -> None:
        with self._lock:
            self._queues.setdefault(req.model_name, deque()).append(req)

    def requeue_front(self, reqs: List[DSERequest]) -> None:
        """Push popped requests back to the head of their queue in their
        original order (dispatch-failure recovery: nothing is lost, the
        next step retries them)."""
        with self._lock:
            for req in reversed(reqs):
                self._queues.setdefault(req.model_name,
                                        deque()).appendleft(req)

    def pending(self, model_name: Optional[str] = None) -> int:
        with self._lock:
            if model_name is not None:
                return len(self._queues.get(model_name, ()))
            return sum(len(q) for q in self._queues.values())

    def models_with_work(self) -> List[str]:
        with self._lock:
            return [m for m, q in self._queues.items() if q]

    def shed(self, predicate: Callable[[DSERequest], bool]
             ) -> List[DSERequest]:
        """Remove (and return) every queued request matching ``predicate``,
        preserving FIFO order among survivors and pruning drained queues.
        The admission-control hook: the server sheds expired-deadline
        requests here, *before* they can occupy a dispatch slot."""
        with self._lock:
            out: List[DSERequest] = []
            for name in list(self._queues):
                q = self._queues[name]
                kept = deque()
                for req in q:
                    (out if predicate(req) else kept).append(req)
                if kept:
                    self._queues[name] = kept
                else:
                    del self._queues[name]
            return out

    def next_batch(self, model_name: Optional[str] = None,
                   rotate: Optional[bool] = None) -> Optional[MicroBatch]:
        """Pop up to ``max_batch`` queued requests (FIFO; round-robin over
        models when ``model_name`` is None) and coalesce them into one
        padded micro-batch.  Returns None when nothing is queued.

        A queue drained by the pop is pruned from the table (the dict used
        to grow one dead entry per retired model under model churn), and
        the round-robin order rotates only on round-robin pops (``rotate``
        defaults to exactly that) — a targeted ``next_batch(model_name=…)``
        does not steal the models behind the target their turn.  The
        server's backoff-aware formation passes an explicit model *and*
        ``rotate=True``: it pre-selects the round-robin head itself (to
        skip models in a retry-backoff window) and the rotation must still
        happen.
        """
        with self._lock:
            round_robin = model_name is None
            if round_robin:
                work = self.models_with_work()
                if not work:
                    return None
                model_name = work[0]
            if rotate is None:
                rotate = round_robin
            q = self._queues.get(model_name)
            if not q:
                return None
            reqs = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
            if not q:
                del self._queues[model_name]
            elif rotate:
                # rotate to the back so multi-model queues share dispatches
                self._queues.move_to_end(model_name)

        m = len(reqs)
        tasks = DSETask.concat([r.as_task() for r in reqs])
        seeds = np.array([r.seed for r in reqs], np.int64)
        k = self._shards()
        per_shard = -(-m // k)       # ceil(m / k)
        if self.pad_pow2:
            per_shard = pow2_bucket(per_shard, floor=1)
        target = per_shard * k
        if target > m:
            rows = np.concatenate([np.arange(m),
                                   np.full(target - m, m - 1)])
            tasks = tasks.take(rows)
            seeds = seeds[rows]
        return MicroBatch(model_name=model_name, requests=reqs,
                          tasks=tasks, seeds=seeds)
