"""The conditional GAN of GANDSE (paper §4, §6.1, Table 4).

Generator  G(net_params, objectives, noise) -> per-config-group one-hot
           probability distributions (softmax per group).
Discriminator D(net_params, config_onehot, objectives) -> satisfaction
           logits (2-class one-hot, like other classification tasks).

Both are multilayer perceptrons with ReLU activations and Adam optimizers
(Table 4).  Params are pure pytrees; everything jit-able.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import ConfigSpace, padded_group_layout
from repro.nn import layers as L

#: shared with encoding.py — the padded per-group layout is also the basis of
#: the explorer's on-device candidate enumeration
_padded_layout = padded_group_layout


@dataclasses.dataclass(frozen=True)
class GANConfig:
    """Hyperparameters (paper Table 4; reduced defaults for CPU CI)."""

    n_net: int                    # encoded network-parameter width
    n_obj: int = 2                # latency + power objectives
    noise_dim: int = 8            # "small random numbers as noise"
    g_hidden_layers: int = 11
    g_neurons: int = 2048
    d_hidden_layers: int = 11
    d_neurons: int = 2048
    g_lr: float = 2e-5
    d_lr: float = 2e-5
    w_critic: float = 0.5
    batch_size: int = 1024
    dtype: str = "float32"
    #: Pallas fused-MLP fast path: None = backend auto (TPU on, CPU/GPU
    #: off), True/False force it (kernels/dispatch.py is the one rule).
    #: Threads through training (per-layer fused_dense with its
    #: custom_vjp) and inference (layer-chained megakernel).
    use_fused: Optional[bool] = None

    def scaled(self, layers: int, neurons: int, lr: float | None = None,
               batch_size: int | None = None) -> "GANConfig":
        """Reduced-scale variant (CPU CI); same algorithm."""
        return dataclasses.replace(
            self,
            g_hidden_layers=layers, d_hidden_layers=layers,
            g_neurons=neurons, d_neurons=neurons,
            g_lr=lr or self.g_lr, d_lr=lr or self.d_lr,
            batch_size=batch_size or self.batch_size,
        )


def init_generator(rng, cfg: GANConfig, space: ConfigSpace):
    in_dim = cfg.n_net + cfg.n_obj + cfg.noise_dim
    hidden = [cfg.g_neurons] * cfg.g_hidden_layers
    return L.mlp_init(rng, in_dim, hidden, space.onehot_width)


def init_discriminator(rng, cfg: GANConfig, space: ConfigSpace):
    in_dim = cfg.n_net + space.onehot_width + cfg.n_obj
    hidden = [cfg.d_neurons] * cfg.d_hidden_layers
    return L.mlp_init(rng, in_dim, hidden, 2)


def generator_apply(params, space: ConfigSpace, net_enc, obj_enc, noise,
                    use_fused: Optional[bool] = None, chained: bool = False,
                    interpret: bool = False):
    """Returns (B, onehot_width) per-group softmax probabilities.

    ``use_fused`` follows the dispatch rule (None = backend auto);
    ``chained=True`` takes the layer-chained megakernel on the fused
    route — the inference fast path (training wants the per-layer
    backward, so the train step leaves it False).
    """
    x = jnp.concatenate([net_enc, obj_enc, noise], axis=-1)
    if chained:
        logits = L.mlp_apply_chained(params, x, use_fused=use_fused,
                                     interpret=interpret)
    else:
        logits = L.mlp_apply(params, x, use_fused=use_fused,
                             interpret=interpret)
    gidx, mask, flat2pad = _padded_layout(space)
    padded = jnp.where(mask, logits[..., gidx], -jnp.inf)
    probs = jax.nn.softmax(padded, axis=-1)      # pad -inf -> exactly 0
    return probs.reshape(*probs.shape[:-2], -1)[..., flat2pad]


def discriminator_apply(params, net_enc, cfg_onehot, obj_enc,
                        use_fused: Optional[bool] = None,
                        interpret: bool = False):
    """Returns (B, 2) satisfaction logits ([False, True] classes)."""
    x = jnp.concatenate([net_enc, cfg_onehot, obj_enc], axis=-1)
    return L.mlp_apply(params, x, use_fused=use_fused, interpret=interpret)


def replicate_params(params, mesh=None):
    """Pin a params pytree replicated across the task mesh — the pure-DP
    layout whose gradients GSPMD all-reduces over the batch axes.  No-op
    when no mesh is active, so single-device callers are untouched."""
    from repro.core import shard
    return shard.replicate(params, mesh)


def sample_noise_dim(rng, batch: int, noise_dim: int):
    """The canonical noise input ("small random numbers"): shared by G and
    the Large-MLP baseline, which §7.1.4 feeds the same noise as G."""
    return jax.random.uniform(rng, (batch, noise_dim), jnp.float32, -0.1, 0.1)


def sample_noise(rng, batch: int, cfg: GANConfig):
    return sample_noise_dim(rng, batch, cfg.noise_dim)


# ---------------------------------------------------------------------------
# losses (all cross-entropy, §6.1)
# ---------------------------------------------------------------------------
def grouped_cross_entropy(space: ConfigSpace, target_onehot, probs) -> jnp.ndarray:
    """E(Config_s, Config_g): summed per-group CE between the dataset
    config (one-hot) and G's per-group distributions.  (B,)

    Because the target is one-hot within each group, the sum of per-group
    CEs equals a single sum over the whole one-hot width — one wide op
    instead of a per-group slice/log/reduce chain (cheaper fwd and bwd).
    """
    eps = 1e-9
    return -jnp.sum(target_onehot * jnp.log(probs + eps), axis=-1)


# a training loss over dataset labels, not a feasibility judge: the oracle
# guarantees finite metrics before they reach here.
# lint: disable=nan-transparent-violation
def satisfaction_ce(logits, sat_true: jnp.ndarray) -> jnp.ndarray:
    """E(Sat, label): 2-class CE; sat_true is bool/float (B,). (B,)"""
    labels = jnp.stack([1.0 - sat_true, sat_true], axis=-1)  # [False, True]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(labels * logp, axis=-1)


def decode_hard(space: ConfigSpace, probs):
    """Per-group argmax -> (B, n_dims) int32 choice indices (jnp)."""
    gidx, mask, _ = _padded_layout(space)
    padded = jnp.where(mask, probs[..., gidx], -jnp.inf)
    return jnp.argmax(padded, axis=-1).astype(jnp.int32)


def indices_to_values(space: ConfigSpace, idx):
    """jnp version of ConfigSpace.values_from_indices (constant tables)."""
    cols = []
    for i, d in enumerate(space.dims):
        table = jnp.asarray(d.choices, jnp.float32)
        cols.append(jnp.take(table, idx[..., i]))
    return jnp.stack(cols, axis=-1)
