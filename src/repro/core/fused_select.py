"""Streaming tiled select: enumerate -> score -> select as ONE program.

The dense batched route (``enumerate_candidates_batch`` + ``select_batch``)
materializes every candidate as a (T, C_pad, n_dims) tensor and walks it
with a sequential length-C_pad Algorithm-2 scan: memory and latency both
scale linearly with the candidate cap, a mid-dispatch host sync picks
C_pad, and the cap tops out at the dense materialization bound
(``explorer._DENSE_LIM`` = 2**20).

This module fuses the three stages into one jitted program that loops
over fixed-size candidate *tiles*:

- each tile step decodes its tile-sized index window by *incremental*
  mixed-radix arithmetic: the in-tile offset digits are divmod-decoded
  once per call (the dense route's ``unravel``, via the shared
  ``explorer._enum_core`` radices) and every tile adds them to the
  running tile-base digits with a carry-propagating compare/subtract —
  zero integer divisions inside the loop (runtime-divisor divmod over
  (T, tile, n_dims) was ~half the route's wall time) and the full
  tensor is never materialized, so peak candidate memory is
  O(T * tile * n_dims) at ANY cap;
- the jnp oracle scores the tile;
- an exact fast-forward of the Algorithm-2 update chain folds the tile
  into the running per-task winner.

Exactness.  Algorithm 2's update chain is path-dependent — whether a row
is accepted depends on the (L_opt, P_opt) carry it meets, so no
carry-independent per-tile argmin/total-order reduction can match the
sequential chain.  Instead the *accept test itself* is vectorized: under
a fixed carry, the chain's next accepted row is simply the first row
whose update predicate holds, so a while-loop of [mask -> jump to first
set bit -> reload carry] replays the sequential chain bit-exactly —
including first-wins tie order — in O(accepted rows) vectorized passes
instead of O(tile) scalar steps.  Accepted rows are rare (each must
improve on the last; measured 1-3 per task over ~900 tiles at cap
2**20), and the accept mask under a fixed carry is cheap to build
row-vectorized — the chain's case split (init/both/sc2/sc3) depends
only on per-task scalars, so the mask is a handful of broadcast
compares that XLA fuses straight into the oracle chain.  The tile step
therefore computes that exact mask once and a ``lax.cond`` runs the
replay loop ONLY on tiles that provably accept a row: the common-tile
cost is one fused mask reduction, no loop machinery.

The tile-loop trip count is ceil(max(total) / tile) computed ON DEVICE —
no ``np.asarray`` mid-dispatch (the GL112 bug class), no recompile (the
program is static in everything but the task-bucket shape), and no
wasted tiles when candidate sets are far below the cap.  Warm serve
dispatch is one uninterrupted device program.

Selections are bit-identical to the dense and host routes (pinned by
``tests/test_fused_select.py``): identical float32 update-chain compares
on identical oracle values, and winner metrics re-derived from the
float64 host oracle through the same ``selections_from_winners`` tail as
``select_batch``.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard
from repro.core.encoding import ConfigSpace
from repro.core.explorer import _PROD_LIM, _enum_core
from repro.core.selector import NOISE_TOL, Selection, selections_from_winners
from repro.design_models.base import DesignModel

#: default tile width — peak candidate memory is O(T * tile * n_dims)
#: regardless of max_candidates, which is how caps up to _PROD_LIM = 2**26
#: fit where the dense route stops at 2**20
FUSED_TILE = 1024


def _fused_batch(model: DesignModel, space: ConfigSpace, tile: int):
    """Build the jitted enumerate->score->select program for one
    (model, tile); cached on the model by `fused_select_batch` the way
    selector caches ``_alg2_batch``."""
    masks_core, radix_core = _enum_core(space)
    rows = jnp.arange(tile, dtype=jnp.int32)
    n_dims = space.n_dims

    def radix_add(base, add, counts):
        # mixed-radix add with the last dim least significant (itertools
        # .product order, same radices as `unravel`); both addends are
        # digit-wise < counts so the ripple carry is at most 1, and the
        # dropped carry-out wraps mod prod(counts) exactly like the
        # divmod form does for indices past the raw product
        digits = []
        carry = jnp.zeros(jnp.broadcast_shapes(base.shape[:-1],
                                               add.shape[:-1]), jnp.int32)
        for d in range(n_dims - 1, -1, -1):
            s = base[..., d] + add[..., d] + carry
            carry = (s >= counts[..., d]).astype(jnp.int32)
            digits.append(s - carry * counts[..., d])
        return jnp.stack(digits[::-1], axis=-1)

    def fold_tile(lo, po, lat, pw, valid, j0, l_opt, p_opt, chosen):
        # exact Algorithm-2 fold of one task's tile (see module docstring):
        # under a fixed carry the accept mask is the update predicate of
        # selector._algorithm2_core, row-vectorized; the first set bit at
        # or after `pos` is the next row the sequential chain accepts.
        fin = jnp.isfinite(lat) & jnp.isfinite(pw) & valid

        def accept(l_opt, p_opt, pos):
            init = (l_opt == 0.0) & (p_opt == 0.0)
            both = ((l_opt > lo) & (p_opt > po)) | ((l_opt < lo) & (p_opt < po))
            sc2 = (l_opt > lo) & (p_opt < po)
            sc3 = (p_opt > po) & (l_opt < lo)
            upd = fin & (
                init
                | (~init & both & (lat < l_opt) & (pw < p_opt))
                | (~init & ~both & sc2 & (lat < l_opt) & (pw < po))
                | (~init & ~both & ~sc2 & sc3 & (pw < p_opt) & (lat < lo))
            )
            return upd & (rows >= pos)

        def cond(state):
            l_opt, p_opt, _chosen, pos = state
            return jnp.any(accept(l_opt, p_opt, pos))

        def body(state):
            l_opt, p_opt, chosen, pos = state
            i = jnp.argmax(accept(l_opt, p_opt, pos)).astype(jnp.int32)
            return lat[i], pw[i], j0 + i, i + jnp.int32(1)

        l_opt, p_opt, chosen, _ = jax.lax.while_loop(
            cond, body, (l_opt, p_opt, chosen, jnp.int32(0)))
        return l_opt, p_opt, chosen

    def run(probs, thresh, cap, net_idx, lo, po):
        keep, counts, total = masks_core(probs, thresh, cap)
        table, stride = radix_core(keep, counts)
        n_tiles = (jnp.max(total) + (tile - 1)) // tile   # device: no sync
        # the ONLY divmod decodes, once per call: in-tile offset digits
        # (T, tile, n_dims) and the per-tile-step digit increment (T, n_dims)
        off_dig = (rows[None, :, None] // stride[:, None, :]) \
            % counts[:, None, :]
        step_dig = (jnp.int32(tile) // stride) % counts

        def decode_and_score(base_dig):
            # the dense `unravel` digit arithmetic on a tile-sized window,
            # via divmod-free incremental add of the tile-base digits
            digit = radix_add(base_dig[:, None, :], off_dig,
                              counts[:, None, :])
            cand = jnp.take_along_axis(table, digit.transpose(0, 2, 1),
                                       axis=-1).transpose(0, 2, 1) \
                .astype(jnp.int32)
            lat, pw = model.evaluate_jax_indices(net_idx[:, None, :], cand)
            return lat.astype(jnp.float32), pw.astype(jnp.float32)

        def tile_step(k, carry):
            l_opt, p_opt, chosen, base_dig = carry
            j0 = (k * tile).astype(jnp.int32)
            valid = (j0 + rows)[None, :] < total[:, None]
            latf, pwf = decode_and_score(base_dig)
            # the EXACT accept mask of the update chain under the incoming
            # carry (== fold_tile's first while cond): the case split is
            # per-task scalars, only the metric compares are per-row, so
            # this fuses into one decode->oracle->mask reduction — the
            # replay runs only on tiles that provably accept a row (1-3
            # per task per run)
            fin = jnp.isfinite(latf) & jnp.isfinite(pwf) & valid
            init = (l_opt == 0.0) & (p_opt == 0.0)
            both = ((l_opt > lo) & (p_opt > po)) | ((l_opt < lo) & (p_opt < po))
            sc2 = (l_opt > lo) & (p_opt < po)
            sc3 = (p_opt > po) & (l_opt < lo)
            lt_l = latf < l_opt[:, None]
            lt_p = pwf < p_opt[:, None]
            upd = fin & (
                init[:, None]
                | ((~init & both)[:, None] & lt_l & lt_p)
                | ((~init & ~both & sc2)[:, None] & lt_l
                   & (pwf < po[:, None]))
                | ((~init & ~both & ~sc2 & sc3)[:, None] & lt_p
                   & (latf < lo[:, None])))

            def replay(c):
                # recompute the tile INSIDE the rare branch: handing latf/
                # pwf to lax.cond as operands would force them (and the
                # whole f64 oracle chain) to materialize every tile,
                # breaking the common path's single fusion — recomputing
                # from the (T, n_dims) carry digits keeps the cond's
                # operands tiny and costs one extra oracle pass on the
                # handful of accepting tiles
                lat2, pw2 = decode_and_score(base_dig)
                return jax.vmap(
                    fold_tile, in_axes=(0, 0, 0, 0, 0, None, 0, 0, 0))(
                    lo, po, lat2, pw2, valid, j0, *c)

            l_opt, p_opt, chosen = jax.lax.cond(
                jnp.any(upd), replay, lambda c: c, (l_opt, p_opt, chosen))
            return l_opt, p_opt, chosen, radix_add(base_dig, step_dig,
                                                   counts)

        t = probs.shape[0]
        carry0 = (jnp.zeros(t, jnp.float32), jnp.zeros(t, jnp.float32),
                  jnp.full((t,), -1, jnp.int32),
                  jnp.zeros((t, space.n_dims), jnp.int32))
        _, _, chosen, _ = jax.lax.fori_loop(0, n_tiles, tile_step, carry0)
        # winner configs from the same mixed radix; rows with chosen < 0
        # yield arbitrary values here and are masked by the host tail
        jw = jnp.maximum(chosen, 0)[:, None]
        digit_w = (jw // stride) % counts
        win = jnp.take_along_axis(table, digit_w[:, :, None], axis=-1)[..., 0]
        return chosen, win.astype(jnp.int32), total

    return jax.jit(run)


def fused_select_batch(
    model: DesignModel,
    net_idx: np.ndarray,
    probs,
    thresh: float,
    max_candidates: int,
    lat_obj,
    pow_obj,
    noise_tol: float = NOISE_TOL,
    tile: int = FUSED_TILE,
) -> List[Selection]:
    """Batched Algorithm 2 straight from generator probs, streaming tiles.

    net_idx (T, n_net_dims), probs (T, onehot_width) (host or device, as
    produced by ``Explorer.generator_probs_device``), objectives (T,).
    Requires a jnp oracle (``model.has_jax_oracle``).  Task t's Selection
    is bit-identical to the dense route's (``enumerate_candidates_batch``
    + ``select_batch``) and to the host route's, at any tile size.

    Under an active task mesh (``shard.set_task_mesh``) with T a multiple
    of the shard count, the inputs land task-sharded and the one fused
    program partitions across devices; the tile axis is never sharded, so
    lane numerics — and winners — are unchanged (the max(total) tile
    bound becomes a deterministic all-reduce).
    """
    assert model.has_jax_oracle, "fused route needs a jnp oracle"
    assert model.space.max_group_size <= 1024 and \
        1 <= max_candidates <= _PROD_LIM, \
        "fused route needs max group size <= 1024 and cap <= 2**26"
    assert tile >= 1
    cache = model.__dict__.setdefault("_fused_select", {})
    run = cache.get(tile)
    if run is None:
        run = cache[tile] = _fused_batch(model, model.space, tile)
    net_idx = np.asarray(net_idx, np.int32)
    lo = np.asarray(lat_obj, np.float64).reshape(-1)
    po = np.asarray(pow_obj, np.float64).reshape(-1)
    chosen, win_cfg, total = run(
        shard.put_sharded(probs), jnp.float32(thresh),
        jnp.int32(max_candidates), shard.put_sharded(net_idx),
        shard.put_sharded(lo.astype(np.float32)),
        shard.put_sharded(po.astype(np.float32)),
    )
    return selections_from_winners(model, net_idx, chosen, win_cfg,
                                   np.asarray(total), lo, po, noise_tol)
