"""Design Selector — Algorithm 2 (paper §6.2), exactly as published.

Scans the candidate configuration sets, keeping (L_opt, P_opt) and the
priority rules:
  scenario 1: both current objectives satisfied or both unsatisfied ->
              update only if the candidate improves BOTH;
  scenario 2: latency unsatisfied, power satisfied -> update if candidate
              improves latency while its power still satisfies PO;
  scenario 3: symmetric to 2.

The candidate metric evaluation is vectorized over the whole candidate set
(one design-model call); only the order-dependent update chain is a scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.design_models.base import DesignModel


@dataclasses.dataclass
class Selection:
    cfg_idx: Optional[np.ndarray]   # (n_dims,) chosen config indices or None
    latency: float
    power: float
    satisfied: bool
    n_candidates: int

    def improvement_ratio(self, lo: float, po: float) -> Optional[float]:
        """sqrt(1/2 ((L-LO)/LO)^2 + 1/2 ((P-PO)/PO)^2) when satisfied (§7.2)."""
        if not self.satisfied:
            return None
        return float(np.sqrt(0.5 * (((self.latency - lo) / lo) ** 2
                                    + ((self.power - po) / po) ** 2)))


def select(
    model: DesignModel,
    net_idx: np.ndarray,
    cand_idx: np.ndarray,
    lat_obj: float,
    pow_obj: float,
    noise_tol: float = 0.01,
) -> Selection:
    """Run Algorithm 2 over the candidate set for one DSE task.

    noise_tol: the paper allows 1% noise when judging satisfaction (§7.2);
    it only affects the reported `satisfied` flag, not the selection chain.
    """
    if cand_idx.size == 0:
        return Selection(None, np.inf, np.inf, False, 0)
    net = np.repeat(np.atleast_2d(net_idx), cand_idx.shape[0], axis=0)
    lat, pw = model.evaluate_indices(net, cand_idx)      # vectorized (lines 4-5)

    lo, po = float(lat_obj), float(pow_obj)
    l_opt, p_opt, chosen = 0.0, 0.0, -1
    for i in range(cand_idx.shape[0]):
        lg, pg = float(lat[i]), float(pw[i])
        if not (np.isfinite(lg) and np.isfinite(pg)):
            continue
        update = False
        if l_opt == 0.0 and p_opt == 0.0:                 # lines 7-8 (init)
            update = True
        elif (l_opt > lo and p_opt > po) or (l_opt < lo and p_opt < po):
            if lg < l_opt and pg < p_opt:                  # lines 10-13
                update = True
        elif l_opt > lo and p_opt < po:                    # lines 15-18
            if lg < l_opt and pg < po:
                update = True
        elif p_opt > po and l_opt < lo:                    # lines 20-22
            if pg < p_opt and lg < lo:
                update = True
        if update:                                         # lines 26-30
            l_opt, p_opt, chosen = lg, pg, i

    if chosen < 0:
        return Selection(None, np.inf, np.inf, False, int(cand_idx.shape[0]))
    satisfied = (l_opt <= lo * (1 + noise_tol)) and (p_opt <= po * (1 + noise_tol))
    return Selection(
        cfg_idx=cand_idx[chosen].copy(),
        latency=l_opt,
        power=p_opt,
        satisfied=bool(satisfied),
        n_candidates=int(cand_idx.shape[0]),
    )
