"""Design Selector — Algorithm 2 (paper §6.2), exactly as published.

Scans the candidate configuration sets, keeping (L_opt, P_opt) and the
priority rules:
  scenario 1: both current objectives satisfied or both unsatisfied ->
              update only if the candidate improves BOTH;
  scenario 2: latency unsatisfied, power satisfied -> update if candidate
              improves latency while its power still satisfies PO;
  scenario 3: symmetric to 2.

The candidate metric evaluation is vectorized over the whole candidate set
(one design-model call); only the order-dependent update chain is a scan.

Models with a jnp oracle (``DesignModel.evaluate_jax``) run the whole
thing — candidate scoring AND the update chain — as one jitted
``jax.lax.scan`` on device; candidate sets are padded to the next power of
two so the jit cache stays small.  Models without a jnp port fall back to
the original host loop.  ``select_batch`` vmaps the same scan over a task
batch so all (T, C_pad) oracle evaluations and update chains resolve in a
single dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard
from repro.core.explorer import pow2_bucket
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class Selection:
    cfg_idx: Optional[np.ndarray]   # (n_dims,) chosen config indices or None
    latency: float
    power: float
    satisfied: bool
    n_candidates: int

    def improvement_ratio(self, lo: float, po: float) -> Optional[float]:
        """sqrt(1/2 ((L-LO)/LO)^2 + 1/2 ((P-PO)/PO)^2) when satisfied (§7.2)."""
        if not self.satisfied:
            return None
        return float(np.sqrt(0.5 * (((self.latency - lo) / lo) ** 2
                                    + ((self.power - po) / po) ** 2)))


#: the paper allows 1% noise when judging satisfaction (§7.2); shared by
#: every DSE method (selector routes, SA, DRL) so the Table-5 comparison
#: judges all of them by the same tolerance
NOISE_TOL = 0.01


def is_satisfied(lat: float, pw: float, lo: float, po: float,
                 noise_tol: float = NOISE_TOL) -> bool:
    """§7.2 satisfaction: both metrics within (1 + noise_tol) of the
    objectives; non-finite metrics never satisfy.  The single definition
    every DSE method reports through."""
    return bool(np.isfinite(lat) and np.isfinite(pw)
                and lat <= lo * (1 + noise_tol)
                and pw <= po * (1 + noise_tol))


#: auto-route cutover: below this candidate count the host numpy loop is
#: faster than dispatching the jitted scan (see `select` docstring).
#: `benchmarks/bench_select_fused.py` reports the crossover it measures on
#: the current host next to this configured value.
JAX_MIN_CANDIDATES = 512
#: historical private name (pre-dating the `--select-route` override)
_JAX_MIN_CANDIDATES = JAX_MIN_CANDIDATES

#: process-wide `select` route override: None = auto (candidate-count
#: crossover), False = force the host loop, True = force the device scan.
#: Set via `set_select_route` (the `--select-route` launcher flag).
_SELECT_ROUTE: Optional[bool] = None

_ROUTE_NAMES = {"auto": None, "host": False, "device": True}


def set_select_route(route: str) -> None:
    """Override the per-task `select` auto-route: "auto" restores the
    JAX_MIN_CANDIDATES crossover, "host" forces the float64 numpy loop,
    "device" forces the jitted scan (models without a jnp oracle still
    fall back to host).  Explicit ``use_jax=`` arguments always win."""
    global _SELECT_ROUTE
    if route not in _ROUTE_NAMES:
        raise ValueError(f"select route must be one of {sorted(_ROUTE_NAMES)},"
                         f" got {route!r}")
    _SELECT_ROUTE = _ROUTE_NAMES[route]


def _algorithm2_core(model: DesignModel):
    """Traceable single-task Algorithm 2: score + update chain in one scan.

    valid marks real (non-padding) candidate rows.  Jitted directly for the
    per-task route (`_algorithm2_scan`) and vmapped over a task batch for
    `select_batch` — the update chain sees the same float32 values either
    way, so batching never changes the winner.
    """

    def run(net_idx, cand_idx, valid, lo, po):
        lat, pw = model.evaluate_jax_indices(net_idx[None, :], cand_idx)
        finite = jnp.isfinite(lat) & jnp.isfinite(pw) & valid

        def body(carry, x):
            l_opt, p_opt, chosen = carry
            lg, pg, fin, i = x
            init = (l_opt == 0.0) & (p_opt == 0.0)            # lines 7-8
            both = ((l_opt > lo) & (p_opt > po)) | ((l_opt < lo) & (p_opt < po))
            sc2 = (l_opt > lo) & (p_opt < po)                 # lines 15-18
            sc3 = (p_opt > po) & (l_opt < lo)                 # lines 20-22
            update = fin & (
                init
                | (~init & both & (lg < l_opt) & (pg < p_opt))   # lines 10-13
                | (~init & ~both & sc2 & (lg < l_opt) & (pg < po))
                | (~init & ~both & ~sc2 & sc3 & (pg < p_opt) & (lg < lo))
            )
            l_opt = jnp.where(update, lg, l_opt)              # lines 26-30
            p_opt = jnp.where(update, pg, p_opt)
            chosen = jnp.where(update, i, chosen)
            return (l_opt, p_opt, chosen), None

        n = lat.shape[0]
        carry0 = (jnp.float32(0.0), jnp.float32(0.0), jnp.int32(-1))
        xs = (lat.astype(jnp.float32), pw.astype(jnp.float32), finite,
              jnp.arange(n, dtype=jnp.int32))
        (l_opt, p_opt, chosen), _ = jax.lax.scan(body, carry0, xs)
        return l_opt, p_opt, chosen

    return run


def _algorithm2_scan(model: DesignModel):
    """Jitted per-task Algorithm 2 (cached on the model); recompiles only
    per padded candidate count."""
    return jax.jit(_algorithm2_core(model))


def _algorithm2_batch(model: DesignModel):
    """Batched Algorithm 2: the single-task scan vmapped over tasks, so all
    (T, C_pad) oracle evaluations and update chains run as ONE jitted
    program (one dispatch for the whole task batch)."""
    return jax.jit(jax.vmap(_algorithm2_core(model)))


def _select_jax(
    model: DesignModel,
    net_idx: np.ndarray,
    cand_idx: np.ndarray,
    lat_obj: float,
    pow_obj: float,
    noise_tol: float,
) -> Selection:
    run = model.__dict__.get("_alg2_scan")
    if run is None:
        run = model.__dict__["_alg2_scan"] = _algorithm2_scan(model)
    # accept (n_net_dims,) or (1, n_net_dims) like the host route does
    net_idx = np.asarray(net_idx, np.int32).reshape(-1)
    n = cand_idx.shape[0]
    n_pad = pow2_bucket(n)                      # next pow2: bounds jit cache
    valid = np.zeros(n_pad, bool)
    valid[:n] = True
    pad = np.zeros((n_pad - n, cand_idx.shape[1]), cand_idx.dtype)
    l_opt, p_opt, chosen = run(
        jnp.asarray(net_idx),
        jnp.asarray(np.concatenate([cand_idx, pad], axis=0)),
        jnp.asarray(valid),
        jnp.float32(lat_obj), jnp.float32(pow_obj),
    )
    chosen = int(chosen)
    if chosen < 0:
        return Selection(None, np.inf, np.inf, False, n)
    # report the winner's metrics from the float64 host oracle so the
    # returned (latency, power, satisfied) are precision-consistent with
    # the host route; the scan's float32 only steered the update chain.
    lat64, pw64 = model.evaluate_indices(net_idx[None], cand_idx[chosen][None])
    l_opt, p_opt = float(lat64[0]), float(pw64[0])
    lo, po = float(lat_obj), float(pow_obj)
    satisfied = is_satisfied(l_opt, p_opt, lo, po, noise_tol)
    return Selection(cand_idx[chosen].copy(), l_opt, p_opt, satisfied, n)


def select(
    model: DesignModel,
    net_idx: np.ndarray,
    cand_idx: np.ndarray,
    lat_obj: float,
    pow_obj: float,
    noise_tol: float = NOISE_TOL,
    use_jax: Optional[bool] = None,
) -> Selection:
    """Run Algorithm 2 over the candidate set for one DSE task.

    noise_tol: the paper allows 1% noise when judging satisfaction (§7.2);
    it only affects the reported `satisfied` flag, not the selection chain.
    use_jax: None = device scan when the model has a jnp oracle AND the
    candidate set is large enough to beat a device dispatch (measured
    crossover ~512 on CPU: 3x faster at the 4096 cap, slower below ~256);
    True/False force a route.  The device route scores candidates in
    float32 (the update chain can pick a different near-tied winner than
    the float64 host loop), but the returned metrics and `satisfied` are
    always computed from the float64 host oracle on the chosen config.
    """
    if cand_idx.size == 0:
        return Selection(None, np.inf, np.inf, False, 0)
    if use_jax is None:
        if _SELECT_ROUTE is None:
            use_jax = (model.has_jax_oracle
                       and cand_idx.shape[0] >= JAX_MIN_CANDIDATES)
        else:       # --select-route override (see set_select_route)
            use_jax = _SELECT_ROUTE and model.has_jax_oracle
    if use_jax:
        return _select_jax(model, net_idx, cand_idx, lat_obj, pow_obj, noise_tol)
    net = np.repeat(np.atleast_2d(net_idx), cand_idx.shape[0], axis=0)
    lat, pw = model.evaluate_indices(net, cand_idx)      # vectorized (lines 4-5)

    lo, po = float(lat_obj), float(pow_obj)
    l_opt, p_opt, chosen = 0.0, 0.0, -1
    for i in range(cand_idx.shape[0]):
        lg, pg = float(lat[i]), float(pw[i])
        if not (np.isfinite(lg) and np.isfinite(pg)):
            continue
        update = False
        if l_opt == 0.0 and p_opt == 0.0:                 # lines 7-8 (init)
            update = True
        elif (l_opt > lo and p_opt > po) or (l_opt < lo and p_opt < po):
            if lg < l_opt and pg < p_opt:                  # lines 10-13
                update = True
        elif l_opt > lo and p_opt < po:                    # lines 15-18
            if lg < l_opt and pg < po:
                update = True
        elif p_opt > po and l_opt < lo:                    # lines 20-22
            if pg < p_opt and lg < lo:
                update = True
        if update:                                         # lines 26-30
            l_opt, p_opt, chosen = lg, pg, i

    if chosen < 0:
        return Selection(None, np.inf, np.inf, False, int(cand_idx.shape[0]))
    satisfied = is_satisfied(l_opt, p_opt, lo, po, noise_tol)
    return Selection(
        cfg_idx=cand_idx[chosen].copy(),
        latency=l_opt,
        power=p_opt,
        satisfied=satisfied,
        n_candidates=int(cand_idx.shape[0]),
    )


def selections_from_winners(
    model: DesignModel,
    net_idx: np.ndarray,
    chosen,
    win_cfg,
    n_candidates,
    lat_obj,
    pow_obj,
    noise_tol: float = NOISE_TOL,
) -> List[Selection]:
    """Shared host tail of the batched device routes (`select_batch` and
    the fused tiled route, ``core/fused_select``): given each task's
    chosen candidate rank (-1 = none feasible) and winner config rows,
    one batched float64 host-oracle call re-derives the reported metrics
    — the device float32 only steered the update chains.  Rows with
    ``chosen[t] < 0`` may hold arbitrary ``win_cfg`` values; they are
    never evaluated."""
    chosen = np.asarray(chosen)
    win = np.asarray(win_cfg)
    net_idx = np.asarray(net_idx, np.int32)
    lo = np.asarray(lat_obj, np.float64).reshape(-1)
    po = np.asarray(pow_obj, np.float64).reshape(-1)
    has = chosen >= 0
    if has.any():       # one float64 host-oracle call for every winner
        lat64, pw64 = model.evaluate_indices(net_idx[has], win[has])

    out, k = [], 0
    for t in range(chosen.shape[0]):
        n = int(n_candidates[t])
        if not has[t]:
            out.append(Selection(None, np.inf, np.inf, False, n))
            continue
        l_opt, p_opt = float(lat64[k]), float(pw64[k])
        k += 1
        satisfied = is_satisfied(l_opt, p_opt, lo[t], po[t], noise_tol)
        out.append(Selection(win[t].copy(), l_opt, p_opt, satisfied, n))
    return out


def select_batch(
    model: DesignModel,
    net_idx: np.ndarray,
    cand_idx,
    valid,
    n_candidates: np.ndarray,
    lat_obj: np.ndarray,
    pow_obj: np.ndarray,
    noise_tol: float = NOISE_TOL,
) -> List[Selection]:
    """Batched device Algorithm 2 over a padded candidate tensor.

    net_idx (T, n_net_dims), cand_idx (T, C_pad, n_dims), valid (T, C_pad)
    (as produced by ``enumerate_candidates_batch``), n_candidates (T,) real
    per-task counts, objectives (T,).  Requires a jnp oracle
    (``model.has_jax_oracle``).

    All T update chains run as one jitted vmapped scan; like the per-task
    device route, candidates are scored in float32 but the winners' reported
    metrics and `satisfied` come from one batched float64 host-oracle call.
    Task t's Selection equals ``select(model, net_idx[t],
    cand_idx[t][:n_candidates[t]], ..., use_jax=True)``.

    Under an active task mesh (``shard.set_task_mesh``) with T a multiple
    of the shard count, all inputs land task-sharded and the vmapped scan
    partitions across devices — same per-lane update chain, same winners.
    """
    run = model.__dict__.get("_alg2_batch")
    if run is None:
        run = model.__dict__["_alg2_batch"] = _algorithm2_batch(model)
    net_idx = np.asarray(net_idx, np.int32)
    n_tasks = net_idx.shape[0]
    lo = np.asarray(lat_obj, np.float64).reshape(-1)
    po = np.asarray(pow_obj, np.float64).reshape(-1)
    _, _, chosen = run(
        shard.put_sharded(net_idx), shard.put_sharded(cand_idx),
        shard.put_sharded(valid),
        shard.put_sharded(lo.astype(np.float32)),
        shard.put_sharded(po.astype(np.float32)),
    )
    chosen = np.asarray(chosen)
    cand_host = np.asarray(cand_idx)
    win_cfg = cand_host[np.arange(n_tasks), np.maximum(chosen, 0)]
    return selections_from_winners(model, net_idx, chosen, win_cfg,
                                   n_candidates, lo, po, noise_tol)
