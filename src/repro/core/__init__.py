"""GANDSE core: the paper's primary contribution (GAN-based DSE).

Lazy re-exports to avoid import cycles (design_models depends on
core.encoding; dse_api depends on design_models).
"""
_EXPORTS = {
    "GANDSE": ("repro.core.dse_api", "GANDSE"),
    "DSEResult": ("repro.core.dse_api", "DSEResult"),
    "parse_network": ("repro.core.dse_api", "parse_network"),
    "summarize": ("repro.core.dse_api", "summarize"),
    "GANConfig": ("repro.core.gan", "GANConfig"),
    "Explorer": ("repro.core.explorer", "Explorer"),
    "ExplorerConfig": ("repro.core.explorer", "ExplorerConfig"),
    "Selection": ("repro.core.selector", "Selection"),
    "select": ("repro.core.selector", "select"),
    "ConfigSpace": ("repro.core.encoding", "ConfigSpace"),
    "ConfigDim": ("repro.core.encoding", "ConfigDim"),
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        mod, attr = _EXPORTS[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(name)
