"""High-level GANDSE API: the four phases of Fig. 4.

- Training phase: ``GANDSE.train`` (once per design template / design model)
- Parsing phase:  ``parse_network`` (abstract layer description -> net params)
- Exploration:    ``GANDSE.explore`` (G inference -> candidates -> Algorithm 2)
  and its batched device-resident twin ``GANDSE.explore_batch`` (one
  dispatch chain for a whole task batch; what ``explore_tasks`` routes to)
- Implementation: ``GANDSE.emit_config`` (structured artifact; stands in for
  the paper's RTL generator, see DESIGN.md §2)
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Dict, List, Optional, Protocol, Sequence, Union,
                    runtime_checkable)

import numpy as np

from repro.core import gan as G
from repro.core import shard
from repro.core.explorer import Explorer, ExplorerConfig, row_seeds  # noqa: F401
# (row_seeds re-exported: the per-row seed convention lives next to
# task_keys so the device and host routes cannot drift apart)
from repro.core.fused_select import fused_select_batch
from repro.core.selector import Selection, select, select_batch
from repro.core.train import TrainState, train_gan
from repro.dataset.generator import Dataset, DSETask, generate_dataset
from repro.design_models.base import DesignModel


def parse_network(desc: Dict[str, float], model: DesignModel) -> np.ndarray:
    """Parsing phase: {'IC':64, 'OC':32, ...} -> net-space indices.

    Values are snapped to the nearest legal sampled value (the dataset
    generator covers the space evenly, §7.1.2), so a second parse of the
    snapped values is a fixed point.
    """
    names = [d.name for d in model.net_space.dims]
    vals = np.array([[float(desc[n]) for n in names]])
    return model.net_space.indices_from_values(vals)[0]


#: scalar-or-per-row-array seed accepted by every batch entry point
SeedLike = Union[int, np.ndarray]


def cache_key(model_name: str, net_idx: np.ndarray, lat_obj: float,
              pow_obj: float, seed: int) -> tuple:
    """Hashable identity of one DSE task row: what the serving result cache
    keys on.  Two submissions with equal keys are guaranteed the same
    Selection by the batched-vs-sequential parity contract (the per-task
    noise key is PRNGKey(seed), independent of batch placement), so a
    cached result is indistinguishable from a recompute — until the
    engine's params change (`DSEServer.swap` invalidates the model's
    entries).
    """
    return (str(model_name),
            tuple(int(v) for v in np.asarray(net_idx).reshape(-1)),
            float(lat_obj), float(pow_obj), int(seed))


@dataclasses.dataclass
class DSEResult:
    selection: Selection
    lat_obj: float
    pow_obj: float
    dse_seconds: float

    @property
    def satisfied(self) -> bool:
        return self.selection.satisfied

    @property
    def improvement_ratio(self) -> Optional[float]:
        return self.selection.improvement_ratio(self.lat_obj, self.pow_obj)


@runtime_checkable
class DSEMethod(Protocol):
    """What every DSE engine speaks — GANDSE and all baselines.

    The comparison harness (experiments/run_comparison.py) and Table-5
    benchmarks treat methods uniformly through this protocol:

    - ``train(n_data, iters, seed=, ds=, log_every=)``: fit on a (shared)
      dataset; model-free methods (SA, random search) accept the call as a
      no-op so one loop drives every method.
    - ``explore(net_idx, lat_obj, pow_obj, seed=)``: one DSE task ->
      ``DSEResult``.
    - ``explore_tasks(tasks, seed=)``: a task batch -> ``List[DSEResult]``.
      Methods with a device route serve the batch in one dispatch chain and
      fall back to the sequential host loop for models without a jnp oracle
      (the ``use_jax_oracle`` rule).  ``seed`` is a scalar (row t explores
      with seed + t) or a (T,) per-row seed array — the array form is how
      the serving layer keeps coalesced requests' results independent of
      micro-batch placement.
    """

    model: DesignModel
    method_name: str

    def train(self, n_data: int, iters: int, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0) -> object: ...

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> "DSEResult": ...

    def explore_tasks(self, tasks: DSETask, seed: SeedLike = 0
                      ) -> List["DSEResult"]: ...


class GANDSE:
    """End-to-end framework object for one design template (design model)."""

    method_name = "GANDSE"

    def __init__(self, model: DesignModel, gan_cfg: Optional[G.GANConfig] = None,
                 explorer_cfg: Optional[ExplorerConfig] = None):
        self.model = model
        n_net = model.net_space.n_dims
        self.gan_cfg = gan_cfg or G.GANConfig(n_net=n_net)
        assert self.gan_cfg.n_net == n_net
        self.explorer_cfg = explorer_cfg or ExplorerConfig()
        self.ds: Optional[Dataset] = None
        self.state: Optional[TrainState] = None
        self._explorer: Optional[Explorer] = None

    # ---- training phase ----------------------------------------------------
    def train(self, n_data: int, iters: int, seed: int = 0, log_every: int = 0,
              ds: Optional[Dataset] = None) -> TrainState:
        self.ds = ds if ds is not None else generate_dataset(self.model, n_data, seed=seed)
        self.state = train_gan(self.model, self.ds, self.gan_cfg, iters=iters,
                               seed=seed, log_every=log_every)
        self.attach(self.ds, self.state.g_params)
        return self.state

    def set_use_fused(self, use_fused: Optional[bool]) -> "GANDSE":
        """Flip the Pallas fused-MLP dispatch (None = backend auto) — the
        serving-layer override hook.  Rebuilds the explorer when one is
        attached: the compiled forward is cached on (space, gan_cfg), so
        flipping back to a previously used setting never recompiles."""
        self.gan_cfg = dataclasses.replace(self.gan_cfg, use_fused=use_fused)
        if self._explorer is not None:
            assert self.ds is not None    # an attached explorer implies it
            self.attach(self.ds, self._explorer.g_params)
        return self

    @property
    def g_params(self) -> Optional[Dict]:
        """Currently attached generator params (None before
        ``train()``/``attach()``) — what a checkpoint of the serving state
        should save (the online loop's generation-0 checkpoint)."""
        return None if self._explorer is None else self._explorer.g_params

    def attach(self, ds: Dataset, g_params: Dict) -> Explorer:
        """Serving entry: wire a dataset (for its normalizers) and trained
        generator params into the explorer without retraining — e.g. params
        restored from a checkpoint, or a hot-swap after an out-of-band
        retrain.  The compiled G inference is shared across Explorer
        instances (cached on (space, gan_cfg)), so a swap never recompiles.
        """
        self.ds = ds
        self._explorer = Explorer(self.model, ds, g_params, self.gan_cfg,
                                  self.explorer_cfg)
        return self._explorer

    # ---- exploration phase ---------------------------------------------------
    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        assert self._explorer is not None, "call train() or attach() first"
        t0 = time.time()
        cands = self._explorer.candidates(net_idx, lat_obj, pow_obj, seed=seed)
        sel = select(self.model, net_idx, cands, lat_obj, pow_obj)
        return DSEResult(sel, float(lat_obj), float(pow_obj), time.time() - t0)

    def explore_batch(self, tasks: DSETask,
                      seed: SeedLike = 0) -> List[DSEResult]:
        """Batched device-resident exploration: vmapped G inference ->
        fused streaming enumerate/score/select (``core/fused_select``) —
        one uninterrupted device program for the whole task batch, with
        zero mid-dispatch host syncs and candidate caps up to 2**26.
        ``ExplorerConfig.batch_route="dense"`` keeps the reference route
        (materialized candidate tensor + vmapped scan, caps to 2**20);
        Selections are bit-identical either way.  Task i returns the same
        Selection
        as ``explore(tasks.net_idx[i], ..., seed=seed + i)`` — or
        ``seed=seed[i]`` when ``seed`` is a (T,) per-task array — identical
        candidate sets always; the winner too, except when `explore` routes
        a small candidate set through the float64 host loop and two
        near-tied candidates differ by less than float32 resolution (the
        same caveat as `select`'s device route).  dse_seconds is the
        amortized per-task wall-clock (total / n_tasks).  Models without a
        jnp oracle fall back to the sequential host route.

        The task batch is padded to its pow2 bucket (``shard.pad_tasks``,
        repeat-last-row, results discarded), so every in-bucket task count
        reuses one compiled program — the same jit-cache contract the
        serve micro-batcher keeps.  Under an active task mesh
        (``shard.set_task_mesh``) the padded size is additionally a
        multiple of the shard count and the whole chain — G inference,
        candidate enumeration, Algorithm 2 — runs task-sharded across the
        mesh.  Selections are bit-identical to the single-device run.
        """
        assert self._explorer is not None, "call train() or attach() first"
        n_tasks = int(tasks.net_idx.shape[0])
        if n_tasks == 0:
            return []
        if not self.model.has_jax_oracle:
            return self._explore_seq(tasks, seed)
        t0 = time.time()
        seeds = row_seeds(seed, n_tasks)
        tasks_p, seeds, n_real = shard.pad_tasks(tasks, seeds)
        if self.explorer_cfg.batch_route == "dense":
            # reference route: materialized candidate tensor + vmapped scan
            cand, valid, counts = self._explorer.candidates_batch(
                tasks_p.net_idx, tasks_p.lat_obj, tasks_p.pow_obj, seed=seeds)
            sels = select_batch(self.model, tasks_p.net_idx, cand, valid,
                                counts, tasks_p.lat_obj, tasks_p.pow_obj)
        else:
            probs = self._explorer.generator_probs_device(
                tasks_p.net_idx, tasks_p.lat_obj, tasks_p.pow_obj, seed=seeds)
            sels = fused_select_batch(
                self.model, tasks_p.net_idx, probs,
                self.explorer_cfg.prob_threshold,
                self.explorer_cfg.max_candidates,
                tasks_p.lat_obj, tasks_p.pow_obj,
                tile=self.explorer_cfg.select_tile)
        per_task = (time.time() - t0) / n_real
        return [
            DSEResult(sel, float(tasks.lat_obj[i]), float(tasks.pow_obj[i]),
                      per_task)
            for i, sel in enumerate(sels[:n_real])
        ]

    def explore_tasks(self, tasks: DSETask, seed: SeedLike = 0,
                      batched: Optional[bool] = None) -> List[DSEResult]:
        """Explore a task batch.  batched=None (default) routes through
        `explore_batch` whenever the model has a jnp oracle; False forces
        the sequential per-task loop (same results, one dispatch chain per
        task).  seed: scalar or (T,) per-task array (see `explore_batch`)."""
        if batched is None:
            batched = self.model.has_jax_oracle
        if batched:
            return self.explore_batch(tasks, seed=seed)
        return self._explore_seq(tasks, seed)

    def _explore_seq(self, tasks: DSETask, seed: SeedLike) -> List[DSEResult]:
        seeds = row_seeds(seed, tasks.net_idx.shape[0])
        return [
            self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                         seed=seeds[i])
            for i in range(tasks.net_idx.shape[0])
        ]

    # ---- implementation phase ------------------------------------------------
    def emit_config(self, result: DSEResult) -> Dict:
        """Structured design artifact (stands in for RTL emission)."""
        sel = result.selection
        assert sel.cfg_idx is not None
        vals = self.model.space.values_from_indices(sel.cfg_idx[None])[0]
        return {
            "design_model": self.model.name,
            "config": {d.name: v for d, v in zip(self.model.space.dims, vals.tolist())},
            "predicted": {"latency_s": sel.latency, "power_w": sel.power},
            "objectives": {"latency_s": result.lat_obj, "power_w": result.pow_obj},
            "satisfied": sel.satisfied,
        }


def summarize(results: Sequence[DSEResult]) -> Dict[str, float]:
    """Table-5-style metrics: satisfied count, improvement ratio, DSE time,
    candidate count, error stds (Fig. 5).

    Defined (and silent — no numpy RuntimeWarning) for every input: an
    empty result list reports zero counts/times, and metrics that average
    over an empty subset (improvement ratio with nothing satisfied, error
    stds with nothing feasible) report NaN.
    """
    n = len(results)
    sat = [r for r in results if r.satisfied]
    irs = [r.improvement_ratio for r in sat if r.improvement_ratio is not None]
    lerr = [ (r.selection.latency - r.lat_obj) / r.lat_obj
             for r in results if np.isfinite(r.selection.latency) ]
    perr = [ (r.selection.power - r.pow_obj) / r.pow_obj
             for r in results if np.isfinite(r.selection.power) ]
    return {
        "n_tasks": n,
        "n_satisfied": len(sat),
        "improvement_ratio": float(np.mean(irs)) if irs else float("nan"),
        "dse_time_s": float(np.mean([r.dse_seconds for r in results])) if n else 0.0,
        "n_candidates": float(np.mean([r.selection.n_candidates
                                       for r in results])) if n else 0.0,
        "lat_err_std": float(np.std(lerr)) if lerr else float("nan"),
        "pow_err_std": float(np.std(perr)) if perr else float("nan"),
    }
