"""Algorithm 1 — the proposed GAN training scheme.

For each sample s of a batch:
    Config_g <- G(Net_s, LO_s, PO_s)                 (line 5)
    Sat      <- D(Net_s, Config_g, LO_s, PO_s)       (line 6)
    L_g, P_g <- design model(Net_s, Config_g)        (lines 7-8)
    Loss_critic += E(Sat, True)/bs                   (line 9)
    if L_g <= LO_s and P_g <= PO_s:                  (line 10)
        Loss_config += 0;      Loss_dis += E(Sat, True)/bs
    else:
        Loss_config += E(Config_s, Config_g)/bs;  Loss_dis += E(Sat, False)/bs
    update G with Loss_config + w_critic * Loss_critic
    update D with Loss_dis

The design model is an *external, non-differentiable* oracle exactly as in
the paper (Fig. 3(c)): its output enters the losses only as constants
(labels / masks), never in the gradient path.  G's gradients flow through
D (frozen) for the critic term and through the per-group CE for the config
term.

Two oracle routes exist:

- **fused** (default for the built-in models): the design model's pure-jnp
  twin ``DesignModel.evaluate_jax`` is traced straight into the jitted
  step under ``stop_gradient`` — no host round-trip, so a whole epoch runs
  as one ``jax.lax.scan`` over device-resident batches.
- **callback** (fallback for models without a jnp port, e.g. external RTL
  simulators): ``jax.pure_callback`` to the host numpy ``evaluate``, as in
  the original implementation.

``train_gan`` pre-encodes the dataset once, uploads it once, and runs each
epoch as a single jitted scan with the (params, opt-state, rng) carry
donated — the Python interpreter touches the hot path once per epoch, not
once per batch.

On TPU the G/D MLP layers inside the step run through the Pallas fused
dense+bias+ReLU kernels — forward and backward (their custom_vjp) — per
the ``kernels/dispatch.py`` rule; ``GANConfig.use_fused`` overrides it.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core import shard
from repro.core.encoding import binary_log2_encode
from repro.dataset.generator import Dataset
from repro.design_models.base import DesignModel
from repro.optim import adam, apply_updates
from repro.train.shardings import axis_size


@dataclasses.dataclass
class TrainState:
    g_params: dict
    d_params: dict
    g_opt: object
    d_opt: object
    rng: jax.Array
    history: List[Dict[str, float]] = dataclasses.field(default_factory=list)


def _design_model_callback(model: DesignModel):
    """Non-differentiable oracle: (B, n_dims) int indices -> (L, P) float32."""

    def eval_np(cfg_idx, net_idx):
        lat, pw = model.evaluate_indices(np.asarray(net_idx), np.asarray(cfg_idx))
        big = np.float32(3.4e38)
        # NaN (a broken oracle formula) counts as infeasible, not as 0.0
        # "satisfies everything" — mirrored by the fused route.
        lat = np.nan_to_num(lat.astype(np.float32), nan=big, posinf=big)
        pw = np.nan_to_num(pw.astype(np.float32), nan=big, posinf=big)
        return lat, pw

    return eval_np


def make_oracle(model: DesignModel, use_jax_oracle: Optional[bool] = None):
    """Build the in-step oracle: (cfg_idx, net_idx) -> (lat, pw) float32.

    use_jax_oracle: True forces the fused jnp route (raises if the model has
    no ``evaluate_jax``), False forces the host-callback route, None picks
    the fused route whenever the model provides it.  Returns (fn, fused).
    Infinite and NaN metrics are clamped to float32-max (i.e. treated as
    infeasible) so downstream comparisons against objectives stay
    well-defined and identical on both routes.
    """
    if use_jax_oracle is None:
        use_jax_oracle = model.has_jax_oracle
    if use_jax_oracle:
        if not model.has_jax_oracle:
            raise ValueError(f"model {model.name!r} has no jnp oracle")
        big = jnp.float32(3.4e38)

        def fused(cfg_idx, net_idx):
            lat, pw = model.evaluate_jax_indices(net_idx, cfg_idx)
            lat = jnp.nan_to_num(lat.astype(jnp.float32), nan=big, posinf=big)
            pw = jnp.nan_to_num(pw.astype(jnp.float32), nan=big, posinf=big)
            # Pin the oracle outputs as materialized buffers via an explicit
            # gather: XLA CPU's instruction fusion otherwise duplicates the
            # whole elementwise oracle chain into every consumer fusion —
            # in grad programs that re-evaluates the oracle once per
            # (row, one-hot column) of the CE backward and doubles the step
            # time.  Gathers are never re-fused, so this is a cheap barrier.
            iota = jnp.arange(lat.shape[0])
            return lat[iota], pw[iota]

        return fused, True

    host = _design_model_callback(model)

    def callback(cfg_idx, net_idx):
        out_spec = (
            jax.ShapeDtypeStruct((cfg_idx.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((cfg_idx.shape[0],), jnp.float32),
        )
        return jax.pure_callback(
            host, out_spec, cfg_idx, net_idx, vmap_method="sequential"
        )

    return callback, False


def _batch_constrainer(mesh):
    """Sharding constraint pinning each batch leaf's leading (sample) axis
    over the mesh's batch axes — the data-parallel layout of Algorithm 1.
    Identity when the mesh has no task axes (or None), so the unsharded
    trace is byte-identical to the pre-mesh one."""
    axes = shard.task_axes(mesh)
    if axes is None:
        return lambda batch: batch
    from jax.sharding import NamedSharding, PartitionSpec as P

    k = axis_size(mesh, axes)

    def constrain(batch):
        def pin(a):
            if a.ndim == 0 or a.shape[0] % k != 0:
                return a
            spec = [None] * a.ndim
            spec[0] = axes
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, P(*spec)))
        return jax.tree.map(pin, batch)

    return constrain


def _make_step_body(model: DesignModel, cfg: G.GANConfig,
                    use_jax_oracle: Optional[bool] = None,
                    mesh=None):
    """The un-jitted Algorithm 1 update as a scan body over batches.

    Returns (g_optim, d_optim, step_body) where
    step_body(carry, batch) -> (carry, metrics) and
    carry = (g_params, d_params, g_opt, d_opt, rng).

    With a `mesh`, each batch is constrained sample-sharded over the
    mesh's batch axes inside the body: G/D forwards, the oracle, and both
    backward passes partition row-wise, and the batch-mean losses make
    GSPMD all-reduce the gradients over ('pod', 'data') — plain data
    parallelism, params replicated.
    """
    space = model.space
    oracle, _ = make_oracle(model, use_jax_oracle)
    constrain = _batch_constrainer(mesh)

    def losses_g(g_params, d_params, batch, noise):
        probs = G.generator_apply(g_params, space, batch["net_enc"],
                                  batch["obj_enc"], noise,
                                  use_fused=cfg.use_fused)
        # --- external design model on the hard-decoded config (lines 7-8)
        cfg_idx = G.decode_hard(space, probs)
        lat_g, pow_g = oracle(cfg_idx, batch["net_idx"])
        sat_actual = ((lat_g <= batch["lat_obj"]) & (pow_g <= batch["pow_obj"])).astype(jnp.float32)
        sat_actual = jax.lax.stop_gradient(sat_actual)

        # D is frozen here (grads are taken w.r.t. g_params only); gradients
        # flow *through* D into G's probs — that is the critic signal.
        sat_logits = G.discriminator_apply(d_params, batch["net_enc"], probs,
                                           batch["obj_enc"],
                                           use_fused=cfg.use_fused)
        loss_critic = jnp.mean(G.satisfaction_ce(sat_logits, jnp.ones_like(sat_actual)))
        ce_cfg = G.grouped_cross_entropy(space, batch["cfg_onehot"], probs)
        loss_config = jnp.mean((1.0 - sat_actual) * ce_cfg)       # masked (line 11/14)
        loss_g = loss_config + cfg.w_critic * loss_critic
        aux = dict(loss_config=loss_config, loss_critic=loss_critic,
                   probs=probs, sat_actual=sat_actual,
                   sat_rate=jnp.mean(sat_actual))
        return loss_g, aux

    def losses_d(d_params, batch, probs, sat_actual):
        probs = jax.lax.stop_gradient(probs)
        sat_logits = G.discriminator_apply(d_params, batch["net_enc"], probs,
                                           batch["obj_enc"],
                                           use_fused=cfg.use_fused)
        loss_dis = jnp.mean(G.satisfaction_ce(sat_logits, sat_actual))  # lines 12/15
        d_acc = jnp.mean(
            (jnp.argmax(sat_logits, -1).astype(jnp.float32) == sat_actual).astype(jnp.float32)
        )
        return loss_dis, dict(d_acc=d_acc)

    g_optim = adam(cfg.g_lr)
    d_optim = adam(cfg.d_lr)

    def step_body(carry, batch):
        g_params, d_params, g_opt, d_opt, rng = carry
        batch = constrain(batch)
        rng, nrng = jax.random.split(rng)
        noise = G.sample_noise(nrng, batch["net_enc"].shape[0], cfg)
        (loss_g, aux), g_grads = jax.value_and_grad(losses_g, has_aux=True)(
            g_params, d_params, batch, noise
        )
        g_upd, g_opt = g_optim.update(g_grads, g_opt)
        g_params = apply_updates(g_params, g_upd)

        (loss_d, daux), d_grads = jax.value_and_grad(losses_d, has_aux=True)(
            d_params, batch, aux["probs"], aux["sat_actual"]
        )
        d_upd, d_opt = d_optim.update(d_grads, d_opt)
        d_params = apply_updates(d_params, d_upd)

        metrics = dict(
            loss_g=loss_g, loss_d=loss_d,
            loss_config=aux["loss_config"], loss_critic=aux["loss_critic"],
            sat_rate=aux["sat_rate"], d_acc=daux["d_acc"],
        )
        return (g_params, d_params, g_opt, d_opt, rng), metrics

    return g_optim, d_optim, step_body


def make_train_step(model: DesignModel, cfg: G.GANConfig,
                    use_jax_oracle: Optional[bool] = None,
                    mesh=None):
    """Build the jitted per-batch update implementing Algorithm 1.

    Kept as the single-batch entry point (benchmarks, tests); the epoch
    loop in ``train_gan`` scans the same body via ``make_epoch_fn``.
    ``mesh``: see ``_make_step_body`` (data-parallel over its batch axes).
    """
    g_optim, d_optim, step_body = _make_step_body(model, cfg, use_jax_oracle,
                                                  mesh=mesh)

    @jax.jit
    def step(g_params, d_params, g_opt, d_opt, batch, rng):
        carry, metrics = step_body((g_params, d_params, g_opt, d_opt, rng), batch)
        g_params, d_params, g_opt, d_opt, rng = carry
        return g_params, d_params, g_opt, d_opt, rng, metrics

    return g_optim, d_optim, step


def make_epoch_fn(model: DesignModel, cfg: G.GANConfig,
                  use_jax_oracle: Optional[bool] = None,
                  mesh=None):
    """Whole-epoch update: one jitted scan over pre-gathered batches.

    epoch(carry, data, perm) -> (carry, metrics):
      carry = (g_params, d_params, g_opt, d_opt, rng), donated;
      data  = dict of full device-resident encoded dataset arrays (N, ...);
      perm  = (n_batches, batch_size) int32 row indices for this epoch.
    The batch gather happens on device, so per-epoch host work is one
    permutation draw and one dispatch.

    With a ``mesh``, hand in the carry replicated (``shard.replicate``),
    the data replicated, and the perm sharded on its batch-size axis
    (``shard.put_sharded(perm, axis=1)``): each device then gathers only
    its own rows and the scanned step runs data-parallel end to end with
    the donated carry staying replicated — what ``train_gan`` does.
    """
    g_optim, d_optim, step_body = _make_step_body(model, cfg, use_jax_oracle,
                                                  mesh=mesh)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def epoch(carry, data, perm):
        batches = jax.tree.map(lambda a: a[perm], data)
        return jax.lax.scan(step_body, carry, batches)

    return g_optim, d_optim, epoch


@functools.lru_cache(maxsize=16)
def _cached_epoch_fn(model: DesignModel, cfg: G.GANConfig,
                     use_jax_oracle: Optional[bool], mesh):
    """Memoized `make_epoch_fn`: repeated `train_gan` calls on the same
    (model, cfg, oracle route, mesh) — the online loop's incremental
    generations — reuse one jitted epoch instead of retracing per call.
    Keys by model identity (design models are stateless oracles) and by
    `GANConfig`/mesh value; with the training arrays' shapes held constant
    (`repro.serve.online.HardReplay` fixes the dataset size for exactly
    this reason) a warm generation is zero-recompile."""
    return make_epoch_fn(model, cfg, use_jax_oracle, mesh=mesh)


def encode_batch(model: DesignModel, ds: Dataset, idx: np.ndarray) -> Dict[str, np.ndarray]:
    net_idx = ds.net_idx[idx]
    return {
        "net_idx": net_idx.astype(np.int32),
        "net_enc": ds.net_encoded(model, net_idx),
        "cfg_onehot": model.space.onehot_from_indices(ds.cfg_idx[idx]),
        # sample objectives: the sample's own (L, P) are the objectives it
        # satisfies exactly (dataset rows double as (objective, witness)).
        "obj_enc": ds.obj_encoded(ds.latency[idx], ds.power[idx]),
        "lat_obj": ds.latency[idx].astype(np.float32),
        "pow_obj": ds.power[idx].astype(np.float32),
    }


def encode_dataset(model: DesignModel, ds: Dataset) -> Dict[str, jnp.ndarray]:
    """Encode every row once and upload to device (train_gan hot-path)."""
    full = encode_batch(model, ds, np.arange(ds.n))
    return {k: jnp.asarray(v) for k, v in full.items()}


def train_gan(
    model: DesignModel,
    ds: Dataset,
    cfg: G.GANConfig,
    iters: int = 5,
    seed: int = 0,
    log_every: int = 0,
    use_jax_oracle: Optional[bool] = None,
    mesh=None,
    state: Optional[TrainState] = None,
) -> TrainState:
    """Mini-batch alternating training (Algorithm 1, lines 1-21).

    Each iteration is one device-resident ``lax.scan`` over the epoch's
    batches; the dataset is encoded and uploaded exactly once.

    ``state`` warm-starts from an earlier `TrainState` (params, optimizer
    moments, and rng all resume; ``seed`` then only drives the epoch
    permutations): the incremental-training entry the online improvement
    loop (`repro.serve.online`) uses to fine-tune generation N from
    generation N-1 instead of re-initializing.  The jitted epoch is
    memoized on (model, cfg, oracle route, mesh), so warm incremental
    calls do not retrace.

    ``mesh=None`` picks up the active task mesh (``shard.set_task_mesh``);
    with one, each epoch runs data-parallel over the mesh's batch axes —
    replicated donated carry, per-device row gathers, gradients
    all-reduced over ('pod', 'data') — and falls back to the unsharded
    path when the batch size does not divide the shard count.  Losses are
    batch means either way, so sharded training matches single-device up
    to float reduction order (pinned by tests/test_shard.py).
    """
    mesh = shard.get_task_mesh() if mesh is None else mesh
    if shard.n_task_shards(mesh) <= 1 or min(cfg.batch_size, ds.n) % \
            shard.n_task_shards(mesh) != 0:
        mesh = None
    g_optim, d_optim, epoch = _cached_epoch_fn(model, cfg, use_jax_oracle,
                                               mesh)
    if state is not None:
        g_params, d_params = state.g_params, state.d_params
        g_opt, d_opt, rng = state.g_opt, state.d_opt, state.rng
    else:
        rng = jax.random.PRNGKey(seed)
        rng, g_rng, d_rng = jax.random.split(rng, 3)
        g_params = G.init_generator(g_rng, cfg, model.space)
        d_params = G.init_discriminator(d_rng, cfg, model.space)
        g_opt = g_optim.init(g_params)
        d_opt = d_optim.init(d_params)

    np_rng = np.random.default_rng(seed)
    n = ds.n
    bs = min(cfg.batch_size, n)
    n_batches = n // bs
    data = encode_dataset(model, ds)
    if mesh is not None:
        data = shard.replicate(data, mesh)

    carry = shard.replicate(
        (g_params, d_params, g_opt, d_opt, rng), mesh)
    history: List[Dict[str, float]] = []
    t0 = time.time()
    for it in range(iters):
        perm = np_rng.permutation(n)[: n_batches * bs]
        perm = perm.reshape(n_batches, bs).astype(np.int32)
        perm = shard.put_sharded(perm, mesh, axis=1) if mesh is not None \
            else jnp.asarray(perm)
        with warnings.catch_warnings():
            # CPU backends can't honor buffer donation; that is fine here.
            warnings.filterwarnings("ignore", message="Some donated buffers")
            carry, metrics = epoch(carry, data, perm)
        metrics = {k: np.asarray(v) for k, v in metrics.items()}
        for b in range(n_batches):
            rec = {k: float(v[b]) for k, v in metrics.items()}
            rec["iter"] = it
            history.append(rec)
        if log_every and (it % log_every == 0):
            m = history[-1]
            print(f"[train_gan] iter={it} loss_g={m['loss_g']:.4f} "
                  f"loss_d={m['loss_d']:.4f} critic={m['loss_critic']:.4f} "
                  f"sat={m['sat_rate']:.3f} t={time.time()-t0:.1f}s")

    g_params, d_params, g_opt, d_opt, rng = carry
    return TrainState(g_params, d_params, g_opt, d_opt, rng, history)
