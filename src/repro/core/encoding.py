"""Feature encoding for GAN-based DSE (paper §6.1).

Configurations are one-hot encoded: "most of the configurations of the
architectures and mapping strategies are not successive and only some
specific numbers are meaningful".  The user's objectives and the network
parameters are encoded as (binary) numbers normalized by the standard
deviation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConfigDim:
    """One configuration dimension with its discrete legal choices."""

    name: str
    choices: Tuple[float, ...]

    @property
    def n(self) -> int:
        return len(self.choices)


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    """The discrete design space: a product of one-hot `ConfigDim`s."""

    dims: Tuple[ConfigDim, ...]

    @property
    def n_dims(self) -> int:
        return len(self.dims)

    @property
    def onehot_width(self) -> int:
        return sum(d.n for d in self.dims)

    @property
    def group_sizes(self) -> Tuple[int, ...]:
        return tuple(d.n for d in self.dims)

    @property
    def size(self) -> int:
        out = 1
        for d in self.dims:
            out *= d.n
        return out

    @property
    def max_group_size(self) -> int:
        return max(d.n for d in self.dims)

    # ---- index <-> value -------------------------------------------------
    def values_from_indices(self, idx: np.ndarray) -> np.ndarray:
        """idx: (..., n_dims) integer choice indices -> (..., n_dims) values."""
        idx = np.asarray(idx)
        cols = [np.asarray(d.choices)[idx[..., i]] for i, d in enumerate(self.dims)]
        return np.stack(cols, axis=-1)

    def indices_from_values(self, vals: np.ndarray) -> np.ndarray:
        vals = np.asarray(vals)
        cols = []
        for i, d in enumerate(self.dims):
            table = np.asarray(d.choices)
            # nearest legal choice (values are expected to be exact members)
            cols.append(np.argmin(np.abs(vals[..., i, None] - table[None, :]), axis=-1))
        return np.stack(cols, axis=-1)

    # ---- one-hot ---------------------------------------------------------
    def onehot_from_indices(self, idx: np.ndarray) -> np.ndarray:
        """(..., n_dims) -> (..., onehot_width) float32 one-hot."""
        idx = np.asarray(idx)
        parts = []
        for i, d in enumerate(self.dims):
            parts.append(np.eye(d.n, dtype=np.float32)[idx[..., i]])
        return np.concatenate(parts, axis=-1)

    def indices_from_onehot(self, oh: np.ndarray) -> np.ndarray:
        """(..., onehot_width) (soft ok) -> argmax per group -> (..., n_dims)."""
        oh = np.asarray(oh)
        out, off = [], 0
        for d in self.dims:
            out.append(np.argmax(oh[..., off : off + d.n], axis=-1))
            off += d.n
        return np.stack(out, axis=-1)

    def split_groups(self, flat):
        """Split a (..., onehot_width) array into per-dim groups (jnp-safe)."""
        out, off = [], 0
        for d in self.dims:
            out.append(flat[..., off : off + d.n])
            off += d.n
        return out

    def sample_indices(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Evenly sample the design space (paper §5.1 dataset generator)."""
        return np.stack(
            [rng.integers(0, d.n, size=n) for d in self.dims], axis=-1
        )

    def split_groups_padded(self, flat, fill=0.0) -> Tuple[jnp.ndarray, np.ndarray]:
        """Batched padded per-group view: (..., onehot_width) -> (..., n_dims,
        max_group_size) with `fill` in the padding slots, plus the (n_dims,
        max_group_size) validity mask.  One wide gather instead of a ragged
        slice chain — the jnp twin of `split_groups` for vectorized per-group
        ops (softmax, threshold masks, argmax) over arbitrary leading dims.
        """
        gidx, mask, _ = padded_group_layout(self)
        return jnp.where(mask, flat[..., gidx], fill), mask

    def values_from_indices_jax(self, idx) -> jnp.ndarray:
        """jnp twin of `values_from_indices`: traceable constant-table gather.

        idx: (..., n_dims) integer choice indices -> (..., n_dims) float32
        values.  The choice tables are baked into the jaxpr as constants so
        design-model oracles built on this stay device-resident.
        """
        cols = [
            jnp.take(jnp.asarray(d.choices, jnp.float32), idx[..., i], axis=0)
            for i, d in enumerate(self.dims)
        ]
        return jnp.stack(cols, axis=-1)


@functools.lru_cache(maxsize=None)
def padded_group_layout(space: ConfigSpace):
    """Constant index maps for vectorized per-group ops.

    Groups have ragged sizes; padding them to (n_dims, max_n) lets per-group
    softmax/threshold/argmax run as ONE wide op instead of a slice/concat
    chain per group (which costs a long tail of small kernels per step).
    Returns (gather_idx (n_dims, max_n), mask, flat_scatter (onehot_width,)):
    ``flat[..., gather_idx]`` -> padded view; ``padded.reshape(..., -1)
    [..., flat_scatter]`` -> flat view.  Plain numpy outputs: they embed as
    jaxpr constants (device arrays here would leak tracers through the
    cache when first built under a trace).
    """
    sizes = space.group_sizes
    mx = max(sizes)
    gidx = np.zeros((len(sizes), mx), np.int32)
    mask = np.zeros((len(sizes), mx), bool)
    flat2pad = np.zeros(space.onehot_width, np.int32)
    off = 0
    for g, n in enumerate(sizes):
        for j in range(n):
            gidx[g, j] = off + j
            mask[g, j] = True
            flat2pad[off + j] = g * mx + j
        off += n
    return gidx, mask, flat2pad


@dataclasses.dataclass(frozen=True)
class Normalizer:
    """Standard-deviation normalization for objectives / net params (§6.1)."""

    mean: np.ndarray
    std: np.ndarray

    @staticmethod
    def fit(x: np.ndarray, center: bool = False) -> "Normalizer":
        x = np.asarray(x, np.float64)
        std = x.std(axis=0)
        std = np.where(std < 1e-12, 1.0, std)
        mean = x.mean(axis=0) if center else np.zeros(x.shape[-1])
        return Normalizer(mean=mean, std=std)

    def __call__(self, x):
        return (x - self.mean) / self.std

    def inverse(self, x):
        return x * self.std + self.mean

    def to_dict(self) -> Dict[str, List[float]]:
        return {"mean": self.mean.tolist(), "std": self.std.tolist()}

    @staticmethod
    def from_dict(d) -> "Normalizer":
        return Normalizer(np.asarray(d["mean"]), np.asarray(d["std"]))


def binary_log2_encode(vals: np.ndarray) -> np.ndarray:
    """Encode positive integer-ish parameters on a log2 scale.

    The paper encodes network parameters 'as the binary numbers'; since all
    net params / choices in Tables 1-3 are powers-of-two-ish magnitudes, a
    log2 magnitude encoding carries the same information in a compact,
    scale-free way and is what we feed the MLPs (then std-normalized).
    """
    return np.log2(np.maximum(np.asarray(vals, np.float64), 1e-9))
