"""Design Explorer — GAN inference + candidate configuration sets (§6.1).

"For each configuration, if the one-hot output of one choice exceeds the
probability threshold, the choice is employed.  Then the candidate
configuration sets are the combinations of all the employed choices of all
the configurations."

The cartesian product can explode combinatorially; we cap it at
``max_candidates`` by greedily trimming the lowest-probability employed
choices (argmax choices are never trimmed), which preserves the paper's
behaviour for realistic thresholds while bounding memory.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core.encoding import ConfigSpace, binary_log2_encode
from repro.dataset.generator import Dataset
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class ExplorerConfig:
    prob_threshold: float = 0.2
    max_candidates: int = 4096
    noise_samples: int = 1     # forward passes with independent noise


def _employed_choices(probs_g: np.ndarray, thresh: float) -> List[np.ndarray]:
    """Per group: indices of choices above threshold (argmax always kept)."""
    out = []
    for g in probs_g:
        keep = np.flatnonzero(g > thresh)
        if keep.size == 0:
            keep = np.array([int(np.argmax(g))])
        out.append(keep)
    return out


def enumerate_candidates(
    space: ConfigSpace,
    probs: np.ndarray,
    thresh: float,
    max_candidates: int,
) -> np.ndarray:
    """probs: (onehot_width,) -> (C, n_dims) int candidate index matrix."""
    groups = [np.asarray(g) for g in space.split_groups(probs)]
    employed = _employed_choices(groups, thresh)

    # cap the cartesian product: repeatedly drop the globally least-probable
    # non-argmax employed choice until the product fits.
    def product_size(emp):
        s = 1
        for e in emp:
            s *= len(e)
        return s

    while product_size(employed) > max_candidates:
        worst_g, worst_i, worst_p = -1, -1, np.inf
        for gi, (g, e) in enumerate(zip(groups, employed)):
            if len(e) <= 1:
                continue
            am = int(np.argmax(g))
            for ci in e:
                if ci == am:
                    continue
                if g[ci] < worst_p:
                    worst_g, worst_i, worst_p = gi, ci, g[ci]
        if worst_g < 0:
            break
        employed[worst_g] = employed[worst_g][employed[worst_g] != worst_i]

    combos = np.array(list(itertools.product(*employed)), dtype=np.int32)
    return combos


@dataclasses.dataclass
class Explorer:
    """Trained-G wrapper: task -> candidate configuration sets."""

    model: DesignModel
    ds: Dataset                 # carries the normalizers
    g_params: dict
    gan_cfg: G.GANConfig
    cfg: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)

    def __post_init__(self):
        space = self.model.space
        gan_cfg = self.gan_cfg

        @functools.partial(jax.jit, static_argnames="n_samples")
        def fwd(g_params, net_enc, obj_enc, rng, n_samples):
            # all noise draws in one dispatch: vmap over folded keys, then
            # average — the whole G inference stays device-resident.
            def one(i):
                noise = G.sample_noise(jax.random.fold_in(rng, i),
                                       net_enc.shape[0], gan_cfg)
                return G.generator_apply(g_params, space, net_enc, obj_enc, noise)

            return jnp.mean(jax.vmap(one)(jnp.arange(n_samples)), axis=0)

        self._fwd = fwd

    def generator_probs(self, net_idx: np.ndarray, lat_obj, pow_obj, seed: int = 0):
        """Batched G forward: (T, onehot_width) mean probs over noise draws."""
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded(np.atleast_1d(lat_obj), np.atleast_1d(pow_obj))
        rng = jax.random.PRNGKey(seed)
        return np.asarray(
            self._fwd(self.g_params, jnp.asarray(net_enc), jnp.asarray(obj_enc),
                      rng, n_samples=self.cfg.noise_samples)
        )

    def candidates(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                   seed: int = 0) -> np.ndarray:
        probs = self.generator_probs(net_idx, lat_obj, pow_obj, seed)[0]
        return enumerate_candidates(
            self.model.space, probs, self.cfg.prob_threshold, self.cfg.max_candidates
        )
