"""Design Explorer — GAN inference + candidate configuration sets (§6.1).

"For each configuration, if the one-hot output of one choice exceeds the
probability threshold, the choice is employed.  Then the candidate
configuration sets are the combinations of all the employed choices of all
the configurations."

The cartesian product can explode combinatorially; we cap it at
``max_candidates`` by trimming the lowest-probability employed choices
(argmax choices are never trimmed), which preserves the paper's behaviour
for realistic thresholds while bounding memory.

Two routes produce identical candidate sets:

- ``enumerate_candidates``: host numpy + ``itertools.product`` for one task;
- ``enumerate_candidates_batch``: the device-resident batch twin — threshold
  mask -> per-group employed counts -> mixed-radix index arithmetic that
  unravels the cartesian product directly into a ``(T, C_pad, n_dims)``
  padded candidate tensor, with ``C_pad`` bucketed to the next power of two
  so the jit cache stays bounded.

A third route consumes the same enumeration *without* the dense tensor:
``core/fused_select`` applies the identical mixed-radix arithmetic to
tile-sized index windows inside one fused enumerate->score->select
program, which is how caps beyond the dense materialization bound
(``_DENSE_LIM``) up to ``_PROD_LIM = 2**26`` are reached.  Both routes
share the traceable cores in ``_enum_core`` so they cannot drift.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core import shard
from repro.core.encoding import ConfigSpace, padded_group_layout
from repro.dataset.generator import Dataset
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class ExplorerConfig:
    prob_threshold: float = 0.2
    max_candidates: int = 4096
    noise_samples: int = 1     # forward passes with independent noise
    #: batched-route selection: "fused" streams candidate tiles through one
    #: enumerate->score->select program (caps up to _PROD_LIM = 2**26);
    #: "dense" keeps the reference route that materializes the (T, C_pad,
    #: n_dims) tensor (caps up to _DENSE_LIM = 2**20).  Selections are
    #: bit-identical either way (tests/test_fused_select.py).
    batch_route: str = "fused"
    #: fused tile width — peak candidate memory is O(T * select_tile * d)
    select_tile: int = 1024


# canonical definition lives beside the padding helpers it feeds;
# re-exported here for the historical import path (selector, batcher)
pow2_bucket = shard.pow2_bucket


def row_seeds(seed, n: int) -> np.ndarray:
    """THE per-row seed convention, shared by every engine route: a scalar
    ``seed`` -> seed + arange(n) (row t explores with seed + t); an (n,)
    array -> as-is (row t explores with seed[t] — how the serve
    micro-batcher keeps coalesced requests' results independent of batch
    placement).  Host int64 either way (see `task_keys`)."""
    if np.ndim(seed) == 0:
        return np.arange(n, dtype=np.int64) + int(seed)
    seeds = np.asarray(seed, np.int64).reshape(-1)
    assert seeds.shape[0] == n, (seeds.shape, n)
    return seeds


def task_keys(seed, n: int) -> jnp.ndarray:
    """Per-task noise keys: PRNGKey over `row_seeds(seed, n)`, masked in
    host int64.

    The sum must not happen in device int32: Python-int seeds >= 2**31 raise
    OverflowError at dispatch, and in-range seeds whose sum crosses 2**31
    wrap mod 2**32 — aliasing task keys with those of other (wrapped) seeds.
    Masking the int64 sum to its low 32 bits before PRNGKey is bitwise
    identical to the legacy int32 route for every seed it accepted
    (including negatives), while keeping any int64 seed valid and collision
    -free within a batch.
    """
    seeds = row_seeds(seed, n) & np.int64(0xFFFFFFFF)
    return jax.vmap(jax.random.PRNGKey)(seeds.astype(np.uint32))


def _employed_choices(probs_g: np.ndarray, thresh: float) -> List[np.ndarray]:
    """Per group: indices of choices above threshold (argmax always kept)."""
    out = []
    for g in probs_g:
        keep = np.flatnonzero(g > thresh)
        if keep.size == 0:
            keep = np.array([int(np.argmax(g))])
        out.append(keep)
    return out


def _trimmed_employed(
    space: ConfigSpace,
    probs: np.ndarray,
    thresh: float,
    max_candidates: int,
) -> List[np.ndarray]:
    """Per-group employed choice sets after the candidate cap (host route)."""
    groups = [np.asarray(g) for g in space.split_groups(probs)]
    employed = _employed_choices(groups, thresh)

    counts = [len(e) for e in employed]
    product = 1
    for c in counts:
        product *= c
    if product > max_candidates:
        # cap the cartesian product: drop non-argmax employed choices in
        # ascending probability order until the product fits (one argsort —
        # dropping a choice never changes the other probabilities, so the
        # ascending order IS the greedy drop-the-global-minimum order; the
        # stable sort resolves ties in group-major, choice-major order, the
        # order a greedy re-scan would visit them).  Argmax choices are
        # never droppable, so the product always reaches <= max_candidates
        # (worst case: every group collapses to its argmax, product 1).
        gis, cis, ps = [], [], []
        for gi, (g, e) in enumerate(zip(groups, employed)):
            am = int(np.argmax(g))
            for ci in e:
                if ci != am:
                    gis.append(gi)
                    cis.append(int(ci))
                    ps.append(g[ci])
        dropped = [set() for _ in groups]
        for k in np.argsort(np.asarray(ps), kind="stable"):
            if product <= max_candidates:
                break
            gi = gis[k]
            dropped[gi].add(cis[k])
            product = product // counts[gi] * (counts[gi] - 1)
            counts[gi] -= 1
        employed = [
            e[~np.isin(e, sorted(d))] if d else e
            for e, d in zip(employed, dropped)
        ]
    return employed


def enumerate_candidates(
    space: ConfigSpace,
    probs: np.ndarray,
    thresh: float,
    max_candidates: int,
) -> np.ndarray:
    """probs: (onehot_width,) -> (C, n_dims) int candidate index matrix."""
    employed = _trimmed_employed(space, probs, thresh, max_candidates)
    return np.array(list(itertools.product(*employed)), dtype=np.int32)


# ---------------------------------------------------------------------------
# device-resident batched enumeration
# ---------------------------------------------------------------------------
#: largest max_candidates any batched route accepts (asserted at entry).
#: Running cartesian-product values are clamped to _PROD_CLAMP during the
#: on-device trim: strictly above any permitted cap, so a clamped value
#: still compares `> cap` correctly.  The divide-form overflow guard in
#: ``_clamped_product`` keeps every partial product exact int32 at this
#: cap (the old multiply-then-min form needed clamp * 1024 < 2**31 and
#: topped out at 2**20).
_PROD_LIM = 1 << 26
_PROD_CLAMP = _PROD_LIM + 1
#: largest cap the *dense* route will materialize as a (T, C_pad, n_dims)
#: tensor; beyond it, only the streaming tiled route (core/fused_select)
#: applies — it never materializes more than a tile.
_DENSE_LIM = 1 << 20


@functools.lru_cache(maxsize=None)
def _enum_core(space: ConfigSpace):
    """Traceable enumeration cores shared by the dense jitted wrappers
    (``_batched_enum_fns``) and the streaming tiled route
    (``core/fused_select``).

    ``masks_core``: probs (T, onehot_width) -> per-group keep masks +
    counts + totals, applying the same threshold/argmax/trim rules as the
    host ``enumerate_candidates`` (bit-for-bit: same probs in -> same sets
    out).  ``radix_core``: the kept sets -> the mixed-radix (table, stride)
    pair whose digit arithmetic unravels the cartesian product in
    ``itertools.product`` order.  One definition feeds both consumers, so
    the routes cannot drift.
    """
    gidx, mask, _ = padded_group_layout(space)
    n_groups, mx = mask.shape
    mask_j = jnp.asarray(mask)

    def _clamped_product(counts):
        # python loop over the (static, small) group count.  The guard is
        # divide-form so the product is only computed when it stays below
        # the clamp (exact for positive ints: p*c > clamp <=> p > clamp//c)
        # — no partial product ever exceeds _PROD_CLAMP < 2**31, at any
        # permitted cap.  The wrapped multiply in the rejected lane of the
        # `where` is discarded, never selected.
        p = jnp.int32(1)
        for g in range(n_groups):
            c = counts[g]
            over = p > _PROD_CLAMP // c
            p = jnp.where(over, jnp.int32(_PROD_CLAMP), p * c)
        return p

    def _masks_one(probs_pad, thresh, cap):
        am = jnp.argmax(probs_pad, axis=-1)
        am_oh = jnp.arange(mx)[None, :] == am[:, None]
        emp = (mask_j & (probs_pad > thresh)) | am_oh    # argmax always kept
        droppable = (emp & ~am_oh).reshape(-1)
        p_flat = jnp.where(droppable, probs_pad.reshape(-1), jnp.inf)
        order = jnp.argsort(p_flat)          # stable: host-loop tie order
        counts0 = emp.sum(axis=-1).astype(jnp.int32)

        def step(counts, slot):
            do = droppable[slot] & (_clamped_product(counts) > cap)
            counts = counts.at[slot // mx].add(-do.astype(jnp.int32))
            return counts, do

        counts, dropped = jax.lax.scan(step, counts0, order)
        keep = emp & ~jnp.zeros_like(droppable).at[order].set(dropped) \
            .reshape(n_groups, mx)
        return keep, counts

    def masks_core(probs, thresh, cap):
        padded, _ = space.split_groups_padded(probs, fill=-jnp.inf)
        keep, counts = jax.vmap(_masks_one, in_axes=(0, None, None))(
            padded, thresh, cap)
        total = jnp.prod(counts, axis=-1)    # <= cap after trim: int32-safe
        return keep, counts, total

    def radix_core(keep, counts):
        table = jnp.argsort(~keep, axis=-1)  # kept slots first, ascending
        # row-major strides (last group fastest — itertools.product order)
        rev = jnp.cumprod(counts[:, ::-1], axis=-1)[:, ::-1]
        stride = jnp.concatenate([rev[:, 1:], jnp.ones_like(rev[:, :1])],
                                 axis=-1)
        return table, stride

    return masks_core, radix_core


@functools.lru_cache(maxsize=None)
def _batched_enum_fns(space: ConfigSpace):
    """Jitted (masks, unravel) pair for the dense on-device enumeration.

    Thin jit wrappers over ``_enum_core``: ``unravel`` applies the mixed
    -radix digit arithmetic to the full [0, c_pad) index range, yielding
    the (T, c_pad, n_dims) padded candidate tensor — ``c_pad`` is static
    so the jit cache holds one entry per power-of-two bucket.
    """
    masks_core, radix_core = _enum_core(space)
    masks = jax.jit(masks_core)

    @functools.partial(jax.jit, static_argnames="c_pad")
    def unravel(keep, counts, total, c_pad):
        table, stride = radix_core(keep, counts)
        j = jnp.arange(c_pad, dtype=jnp.int32)
        digit = (j[None, :, None] // stride[:, None, :]) % counts[:, None, :]
        cand = jnp.take_along_axis(table, digit.transpose(0, 2, 1), axis=-1)
        valid = j[None, :] < total[:, None]
        return cand.transpose(0, 2, 1).astype(jnp.int32), valid

    return masks, unravel


def enumerate_candidates_batch(
    space: ConfigSpace,
    probs,
    thresh: float,
    max_candidates: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Device twin of ``enumerate_candidates`` over a task batch.

    probs: (T, onehot_width) array (host or device) ->
      cand  (T, C_pad, n_dims) int32 device candidate indices,
      valid (T, C_pad) bool device mask of real (non-padding) rows,
      counts (T,) host int per-task candidate counts.

    Row t's first counts[t] candidates equal ``enumerate_candidates`` on
    probs[t] exactly.  C_pad is the next power of two >= max(counts),
    bucketing recompiles to at most log2(max_candidates) cache entries.

    This is the *reference* route: picking C_pad costs a mid-dispatch host
    sync (the ``np.asarray(total)`` below — the GL112 bug class) and the
    tensor caps out at ``_DENSE_LIM``.  The production batched path
    (``core/fused_select``) streams the same enumeration in tiles with
    neither limit.
    """
    assert space.max_group_size <= 1024 and 1 <= max_candidates <= _DENSE_LIM, \
        "dense route needs max group size <= 1024 and cap <= 2**20 " \
        "(use the fused tiled route for larger caps)"
    masks, unravel = _batched_enum_fns(space)
    keep, counts, total = masks(shard.put_sharded(probs), jnp.float32(thresh),
                                jnp.int32(max_candidates))
    counts_host = np.asarray(total)
    c_pad = pow2_bucket(int(counts_host.max(initial=1)))
    cand, valid = unravel(keep, counts, total, c_pad)
    return cand, valid, counts_host


def flatten_task_draws(net_enc, obj_enc, keys, n_samples: int, noise_fn):
    """THE (task, sample) -> row-batch layout of the chained (megakernel)
    inference route, shared by the explorer and the LargeMLP baseline so
    the per-task noise-stream parity contract lives in one place.

    noise_fn(key, s) -> (noise_dim,) draws sample s of a task's stream
    (the same fold_in(key, s) streams the vmap route uses).  Returns
    (net_rows, obj_rows, noise_rows), each (T * n_samples, ·), task-major
    — averaging back is ``rows.reshape(T, n_samples, -1).mean(axis=1)``.
    """
    t = net_enc.shape[0]
    noise = jax.vmap(lambda key: jax.vmap(
        lambda s: noise_fn(key, s))(jnp.arange(n_samples)))(keys)
    rep = lambda a: jnp.repeat(a[:, None], n_samples, axis=1) \
        .reshape(t * n_samples, -1)
    return rep(net_enc), rep(obj_enc), noise.reshape(t * n_samples, -1)


@functools.lru_cache(maxsize=None)
def _cached_fwd(space: ConfigSpace, gan_cfg: G.GANConfig,
                chained: bool = None):
    """Module-level jitted G inference, cached on (space, gan_cfg): a fresh
    Explorer (e.g. per retrain / hot-swap) reuses the compiled forward
    instead of recompiling from scratch.

    Per-task noise streams: task t averages n_samples draws from
    fold_in(keys[t], s) — the same streams whether tasks run one at a time
    or batched, which is the batched-vs-sequential parity contract.

    ``chained`` (None = dispatch auto, i.e. TPU) flattens the (T, samples)
    draws into one row batch and runs G through the layer-chained Pallas
    megakernel — one big dispatch instead of a vmap of width-1 forwards.
    Same noise streams either way; off the fused path the vmap structure
    (and its numerics) is unchanged.
    """
    from repro.kernels import dispatch as D
    if chained is None:
        chained = D.fused_enabled(gan_cfg.use_fused) and D.on_tpu()

    def noise_fn(key, s):
        return G.sample_noise(jax.random.fold_in(key, s), 1, gan_cfg)[0]

    @functools.partial(jax.jit, static_argnames="n_samples")
    def fwd(g_params, net_enc, obj_enc, keys, n_samples):
        if chained:
            t = net_enc.shape[0]
            net_r, obj_r, noise_r = flatten_task_draws(
                net_enc, obj_enc, keys, n_samples, noise_fn)
            probs = G.generator_apply(
                g_params, space, net_r, obj_r, noise_r,
                use_fused=gan_cfg.use_fused, chained=True)
            return jnp.mean(probs.reshape(t, n_samples, -1), axis=1)

        def one_task(net, obj, key):
            def one(s):
                noise = G.sample_noise(jax.random.fold_in(key, s), 1, gan_cfg)
                return G.generator_apply(g_params, space, net[None], obj[None],
                                         noise,
                                         use_fused=gan_cfg.use_fused)[0]
            return jnp.mean(jax.vmap(one)(jnp.arange(n_samples)), axis=0)

        return jax.vmap(one_task)(net_enc, obj_enc, keys)

    return fwd


@dataclasses.dataclass
class Explorer:
    """Trained-G wrapper: task -> candidate configuration sets."""

    model: DesignModel
    ds: Dataset                 # carries the normalizers
    g_params: dict
    gan_cfg: G.GANConfig
    cfg: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)

    def __post_init__(self):
        self._fwd = _cached_fwd(self.model.space, self.gan_cfg)

    def generator_probs_device(self, net_idx: np.ndarray, lat_obj, pow_obj,
                               seed: int = 0) -> jnp.ndarray:
        """Vmapped G forward: (T, onehot_width) device mean probs.

        Task row t draws its noise from PRNGKey(seed + t) — or PRNGKey
        (seed[t]) when ``seed`` is a per-task array — so row t is
        bitwise-equal to a single-task call with that seed: batching a task
        never changes its candidates.  The sum runs in host int64 (see
        `task_keys`) so large seeds neither raise nor alias.

        When a task mesh is active (``shard.set_task_mesh``) and the task
        count divides its shard count, the inputs land task-sharded over
        the mesh and the same jitted forward runs SPMD across devices —
        lane numerics (and thus candidates) are unchanged.
        """
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded(np.atleast_1d(lat_obj),
                                      np.atleast_1d(pow_obj))
        keys = task_keys(seed, net_enc.shape[0])
        return self._fwd(self.g_params, shard.put_sharded(net_enc),
                         shard.put_sharded(obj_enc), shard.put_sharded(keys),
                         n_samples=self.cfg.noise_samples)

    def generator_probs(self, net_idx: np.ndarray, lat_obj, pow_obj,
                        seed: int = 0) -> np.ndarray:
        """Host-array view of `generator_probs_device`."""
        return np.asarray(
            self.generator_probs_device(net_idx, lat_obj, pow_obj, seed))

    def candidates(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                   seed: int = 0) -> np.ndarray:
        probs = self.generator_probs(net_idx, lat_obj, pow_obj, seed)[0]
        return enumerate_candidates(
            self.model.space, probs, self.cfg.prob_threshold, self.cfg.max_candidates
        )

    def candidates_batch(self, net_idx: np.ndarray, lat_obj, pow_obj,
                         seed: int = 0):
        """Device-resident candidates for a task batch: G inference and the
        cartesian-product enumeration both stay on device; see
        `enumerate_candidates_batch` for the return contract."""
        probs = self.generator_probs_device(net_idx, lat_obj, pow_obj, seed)
        return enumerate_candidates_batch(
            self.model.space, probs, self.cfg.prob_threshold,
            self.cfg.max_candidates
        )
