"""Task-axis sharding over a device mesh — the multi-chip DSE scale-out.

Every batched DSE route (``GANDSE.explore_batch``/``select_batch``, the
MLP/SA/DRL device routes behind the same ``DSEMethod`` protocol) vmaps
independent task lanes, so sharding the leading task axis over the mesh's
batch axes ('pod', 'data') is pure throughput: the same jitted programs
compile to one SPMD executable over the mesh (the `jit`-with-shardings
idiom) and per-lane numerics are untouched — sharded and single-device
runs return bit-identical Selections (pinned by tests/test_shard.py).

Usage:

    from repro.core import shard
    from repro.launch.mesh import make_host_mesh

    shard.set_task_mesh(make_host_mesh())       # or the task_mesh() context
    results = engine.explore_tasks(tasks)       # now sharded over the mesh

Mechanics, shared by every route:

1. the task batch is padded to a multiple of the shard count with the
   serve batcher's repeat-last-row rule (``pad_tasks``; padded lanes are
   computed and discarded, and per-row seeds pad along so real rows keep
   their placement-independent noise streams);
2. leading-axis arrays are placed with ``put_sharded`` — a NamedSharding
   over the mesh's batch axes — so jit partitions the existing vmapped
   program across devices instead of recompiling anything new.

The fused streaming select (``core/fused_select``) keeps the same
invariant: ONLY the task axis shards.  Its candidate-tile axis is a
device-local loop dimension — every lane walks its own tiles — and the
one cross-lane value, the max(total) tile-loop bound, lowers to a
deterministic all-reduce, so sharded fused runs stay bit-identical too
(pinned by tests/test_fused_select.py::test_fused_mesh_parity).

Training rides the same mesh through ``train_gan(..., mesh=...)`` (which
defaults to the active task mesh): sharded pre-encoded batches, donated
replicated carries, gradients all-reduced over ('pod', 'data') by GSPMD.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train.shardings import axis_size, batch_axes, norm_axes

_STATE = {"mesh": None}


def set_task_mesh(mesh: Optional[Mesh]) -> Optional[Mesh]:
    """Install `mesh` as the process-wide task mesh (None disables
    sharding); returns the previous mesh so callers can restore it."""
    prev = _STATE["mesh"]
    _STATE["mesh"] = mesh
    return prev


def get_task_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


@contextlib.contextmanager
def task_mesh(mesh: Optional[Mesh]):
    """Scoped ``set_task_mesh`` (tests, benchmarks)."""
    prev = set_task_mesh(mesh)
    try:
        yield mesh
    finally:
        set_task_mesh(prev)


def task_axes(mesh: Optional[Mesh]) -> Optional[Tuple[str, ...]]:
    """The mesh axes the task dim shards over: ('pod', 'data') normalized
    to the axes actually present at size > 1 (None when there are none —
    e.g. a model-only or single-device mesh)."""
    if mesh is None:
        return None
    return norm_axes(batch_axes(mesh), mesh)


def n_task_shards(mesh: Optional[Mesh]) -> int:
    """How many ways the task axis splits on `mesh` (1 = unsharded)."""
    axes = task_axes(mesh)
    return axis_size(mesh, axes) if axes else 1


def active_n_shards() -> int:
    """Shard count of the active task mesh (1 when none is set) — what the
    serve micro-batcher sizes batches by."""
    return n_task_shards(get_task_mesh())


def pow2_bucket(n: int, floor: int = 2) -> int:
    """Smallest power of two >= max(n, floor): the jit-cache bucketing rule
    shared by candidate padding (``C_pad``), Algorithm 2 padding, the serve
    micro-batcher, and ``pad_tasks``, so every dynamic extent compiles at
    most log2(max) programs."""
    return 1 << (max(int(n), floor) - 1).bit_length()


def pad_rows(n: int, multiple: int) -> Optional[np.ndarray]:
    """Row gather padding `n` up to the next multiple with the batcher's
    repeat-last-row rule; None when already aligned."""
    if multiple <= 1 or n % multiple == 0:
        return None
    target = ((n + multiple - 1) // multiple) * multiple
    return np.concatenate([np.arange(n), np.full(target - n, n - 1)])


def pad_tasks(tasks, seeds: np.ndarray, mesh: Optional[Mesh] = None):
    """Pad a task batch (and its per-row seed array) to the batcher's
    bucket: ``n_shards * pow2_bucket(ceil(n / n_shards))`` (plain pow2
    when no mesh is active).  Returns ``(tasks, seeds, n_real)``.  The
    bucketing makes *direct* ``explore_batch`` calls share one jit cache
    entry across every in-bucket task count, the same contract the serve
    micro-batcher keeps for the dispatch path.  Padded rows repeat the
    last real row, seed included; their results are computed and
    discarded, and — the parity contract — they cannot perturb real rows,
    every lane being vmap-independent.
    """
    mesh = get_task_mesh() if mesh is None else mesh
    n = len(tasks)
    if n == 0:
        return tasks, seeds, 0
    shards = max(n_task_shards(mesh), 1)
    target = shards * pow2_bucket(-(-n // shards), floor=1)
    rows = pad_rows(n, target)
    if rows is None:
        return tasks, seeds, n
    return tasks.take(rows), np.asarray(seeds)[rows], n


def put_sharded(x, mesh: Optional[Mesh] = None, axis: int = 0):
    """Place `x` with its `axis` dim sharded over the mesh's task axes.

    Falls back to ``jnp.asarray`` (default single-device placement) when no
    mesh is active, the mesh has no task axes, or the dim does not divide
    the shard count — the exact pre-sharding behavior, so every call site
    is a drop-in replacement for ``jnp.asarray``.
    """
    import jax.numpy as jnp

    mesh = get_task_mesh() if mesh is None else mesh
    axes = task_axes(mesh)
    ndim = np.ndim(x)
    if (axes is None or ndim <= axis
            or np.shape(x)[axis] % axis_size(mesh, axes) != 0):
        return jnp.asarray(x)
    spec = [None] * ndim
    spec[axis] = axes
    return jax.device_put(x, NamedSharding(mesh, P(*spec)))


def replicate(tree, mesh: Optional[Mesh] = None):
    """Replicate a pytree (params, optimizer state) across the mesh — the
    pure-DP layout whose gradients GSPMD all-reduces over the batch axes.
    No-op (identity) when no mesh is active."""
    mesh = get_task_mesh() if mesh is None else mesh
    if mesh is None:
        return tree
    return jax.device_put(tree, NamedSharding(mesh, P()))
