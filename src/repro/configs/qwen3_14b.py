"""qwen3-14b [dense] — 40L d=5120 40H (GQA kv=8) d_ff=17408 vocab=151936,
qk-norm.  [hf:Qwen/Qwen3-14B]"""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "qwen3-14b", "dense", 40, 5120, 40, 8, 17408, 151936,
    head_dim=128, qk_norm=True, tied=False, theta=1e6,
    notes="pure full attention -> long_500k skipped (DESIGN.md §4)",
)

REDUCED = decoder_arch(
    "qwen3-14b-reduced", "dense", 2, 64, 4, 2, 128, 512,
    head_dim=16, qk_norm=True, tied=False,
)
