"""whisper-small [audio] — 12L enc + 12L dec, d=768 12H (kv=12) d_ff=3072
vocab=51865, enc-dec with conv frontend STUB.  [arXiv:2212.04356]

input_specs() provides precomputed frame embeddings (B, S_enc, D) — the
two conv layers of the real frontend halve the mel frame count; the stub
hands the backbone the post-conv sequence directly.  Shape mapping (see
DESIGN.md §4): the cell's seq_len is the ENCODER frame length; the decoder
runs its native 448-token context for training and the cell's KV length
for decode cells."""
from repro.models.builders import encdec_arch

FULL = encdec_arch(
    "whisper-small", 12, 12, 768, 12, 12, 3072, 51865,
    max_enc_len=1500, tied=True,
    notes="enc-dec; long_500k skipped (full-attention enc-dec family)",
)

REDUCED = encdec_arch(
    "whisper-small-reduced", 2, 2, 64, 4, 4, 128, 512,
    max_enc_len=64, tied=True,
)

DECODER_TRAIN_LEN = 448
