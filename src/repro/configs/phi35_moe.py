"""phi3.5-moe-42b-a6.6b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2, full attention.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "phi3.5-moe-42b-a6.6b", "moe", 32, 4096, 32, 8, 6400, 32064,
    head_dim=128, n_experts=16, top_k=2, tied=False,
    notes="pure full attention -> long_500k skipped (DESIGN.md §4)",
)

REDUCED = decoder_arch(
    "phi3.5-moe-reduced", "moe", 2, 64, 4, 2, 96, 512,
    head_dim=16, n_experts=4, top_k=2, tied=False,
)
