"""xlstm-1.3b [ssm] — 48L d=2048 4H, mLSTM:sLSTM = 7:1, d_ff=0 (blocks
carry their own projections), vocab=50304.  [arXiv:2405.04517]"""
from repro.models.builders import xlstm_arch

FULL = xlstm_arch(
    "xlstm-1.3b", 48, 2048, 4, 50304, slstm_every=8, tied=True,
    notes="recurrent state decode: O(1)/token -> long_500k runs",
)

REDUCED = xlstm_arch(
    "xlstm-reduced", 4, 64, 4, 512, slstm_every=2, tied=True,
)
