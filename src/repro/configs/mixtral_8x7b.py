"""mixtral-8x7b [moe] — 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096).  [arXiv:2401.04088]"""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "mixtral-8x7b", "moe", 32, 4096, 32, 8, 14336, 32000,
    head_dim=128, window=4096, n_experts=8, top_k=2, tied=False,
    theta=1e6, sub_quadratic=True,
    notes="SWA(4096) makes every layer banded -> long_500k eligible",
)

REDUCED = decoder_arch(
    "mixtral-8x7b-reduced", "moe", 2, 64, 4, 2, 128, 512,
    head_dim=16, window=32, n_experts=4, top_k=2, tied=False,
    sub_quadratic=True,
)
