"""deepseek-coder-33b [dense] — 62L d=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch.  [arXiv:2401.14196]"""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "deepseek-coder-33b", "dense", 62, 7168, 56, 8, 19200, 32256,
    head_dim=128, tied=False,
    notes="pure full attention -> long_500k skipped (DESIGN.md §4)",
)

REDUCED = decoder_arch(
    "deepseek-coder-reduced", "dense", 2, 64, 4, 2, 128, 512,
    head_dim=16, tied=False,
)
