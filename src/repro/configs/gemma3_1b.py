"""gemma3-1b [dense] — 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local(1024):global interleave, 128k context.  [hf:google/gemma-3-1b-pt]"""
from repro.models.builders import local_global_arch

FULL = local_global_arch(
    "gemma3-1b", "dense", 26, 1152, 4, 1, 6912, 262144,
    head_dim=256, local_window=1024, locals_per_global=5,
    tied=True, theta=1e6,
    notes="dominantly sliding-window -> long_500k runs; 4 global layers "
          "keep a full-length KV cache",
)

REDUCED = local_global_arch(
    "gemma3-1b-reduced", "dense", 7, 64, 4, 1, 128, 512,
    head_dim=16, local_window=32, locals_per_global=5, tied=True,
)
