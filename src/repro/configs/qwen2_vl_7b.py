"""qwen2-vl-7b [vlm] — 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
M-RoPE (temporal/height/width rotary sections), dynamic resolution.
[arXiv:2409.12191]

The vision patch frontend is a STUB: input_specs() provides token ids plus
(3, B, S) M-RoPE position ids (all-equal for text positions; precomputed
patch embeddings would be summed into the embedding stream by the real
frontend)."""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "qwen2-vl-7b", "vlm", 28, 3584, 28, 4, 18944, 152064,
    head_dim=128, mrope=(16, 24, 24), tied=False, theta=1e6,
    notes="pure full attention -> long_500k skipped (DESIGN.md §4); "
          "M-RoPE sections (16,24,24) over the 64 rotary half-dims",
)

REDUCED = decoder_arch(
    "qwen2-vl-reduced", "vlm", 2, 64, 4, 2, 128, 512,
    head_dim=16, mrope=(2, 3, 3), tied=False,
)
