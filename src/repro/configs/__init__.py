"""Architecture registry: ``get_arch(name)`` / ``get_reduced(name)``.

Every assigned architecture is a module exposing FULL and REDUCED
ModelCfg objects; shapes live in ``repro.configs.shapes``.
"""
from __future__ import annotations

import importlib
from typing import List

_ARCHS = (
    "mixtral_8x7b",
    "phi35_moe",
    "stablelm_1_6b",
    "qwen3_14b",
    "gemma3_1b",
    "deepseek_coder_33b",
    "qwen2_vl_7b",
    "whisper_small",
    "xlstm_1_3b",
    "hymba_1_5b",
)

_ALIASES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-14b": "qwen3_14b",
    "gemma3-1b": "gemma3_1b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
    "xlstm-1.3b": "xlstm_1_3b",
    "hymba-1.5b": "hymba_1_5b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def list_archs() -> List[str]:
    return list(_ARCHS)


def _module(name: str):
    return importlib.import_module(f"repro.configs.{canonical(name)}")


def get_arch(name: str):
    return _module(name).FULL


def get_reduced(name: str):
    return _module(name).REDUCED
