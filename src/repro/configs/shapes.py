"""The assigned input shapes (one set, shared by all LM archs)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(arch, shape: Shape) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM/hybrid/sliding);
    every assigned arch has a decoder, so decode shapes always apply."""
    if shape.name == "long_500k":
        return bool(arch.sub_quadratic)
    return True
