"""stablelm-1.6b [dense] — 24L d=2048 32H (kv=32: MHA) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b]"""
from repro.models.builders import decoder_arch

FULL = decoder_arch(
    "stablelm-1.6b", "dense", 24, 2048, 32, 32, 5632, 100352,
    tied=True,
    notes="pure full attention -> long_500k skipped (DESIGN.md §4)",
)

REDUCED = decoder_arch(
    "stablelm-1.6b-reduced", "dense", 2, 64, 4, 4, 128, 512, tied=True,
)
