"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + mamba heads (ssm_state=16); full attention only at
first/middle/last layers, sliding window elsewhere.  [arXiv:2411.13676]"""
from repro.models.builders import sandwich_arch

FULL = sandwich_arch(
    "hymba-1.5b", "hybrid", 32, 1600, 25, 5, 5504, 32001,
    head_dim=64, local_window=1024, ssm_state=16, n_globals=3, tied=True,
    notes="hybrid attn+SSM -> long_500k runs (3 global layers keep a "
          "full-length KV cache)",
)

REDUCED = sandwich_arch(
    "hymba-reduced", "hybrid", 5, 64, 4, 2, 128, 512,
    head_dim=16, local_window=32, ssm_state=8, n_globals=3, tied=True,
)
