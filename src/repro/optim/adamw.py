"""Pure-JAX optimizers (no optax dependency).

Functional API mirroring optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``;
``apply_updates(params, updates) -> params``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def _tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip_norm: Optional[float] = None,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _s, _lr=lr: _lr)

    def init(params):
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=_tree_zeros_like(params),
                         nu=_tree_zeros_like(params))

    def update(grads, state: AdamState, params=None):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p
            return u

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adam(lr, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
