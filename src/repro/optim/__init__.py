from repro.optim.adamw import adam, adamw, apply_updates, clip_by_global_norm  # noqa: F401
from repro.optim.schedule import constant, cosine, linear_warmup_cosine  # noqa: F401
