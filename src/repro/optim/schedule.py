"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * c)

    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return fn
