"""Gradient compression for cross-pod all-reduce (beyond-paper trick).

int8 quantization with error-feedback residual: each step the residual of
the previous quantization is added back before quantizing, so the scheme
is unbiased over time (EF-SGD).  Under pjit the quantize -> all-reduce ->
dequantize pattern lets the slow DCN 'pod' axis carry 4x fewer bytes; the
fast ICI axes still reduce in bf16/f32.

Usage:
    comp = GradCompressor()
    state = comp.init(params)
    grads, state = comp(grads, state)    # inside train_step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    bits: int = 8

    def init(self, params) -> Any:
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def __call__(self, grads, residual) -> Tuple[Any, Any]:
        qmax = float(2 ** (self.bits - 1) - 1)

        def comp(g, r):
            g32 = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / qmax
            q = jnp.clip(jnp.round(g32 / scale), -qmax, qmax).astype(jnp.int8)
            deq = q.astype(jnp.float32) * scale
            return deq.astype(g.dtype), g32 - deq

        out = jax.tree.map(comp, grads, residual)
        new_grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_res
