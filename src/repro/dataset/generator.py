"""Dataset Generator (paper §5.1, §7.1.2).

Evenly samples network parameters, architecture parameters, and mapping
strategies across the design space, evaluates the design model for the
objectives, and assembles the training dataset.  Latency and power are
normalized by the standard deviation (Tables 2-3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.encoding import Normalizer, binary_log2_encode
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class Dataset:
    """Training dataset: one row = (net params, config, latency, power)."""

    model_name: str
    net_idx: np.ndarray        # (N, n_net_dims) int
    cfg_idx: np.ndarray        # (N, n_cfg_dims) int
    latency: np.ndarray        # (N,) seconds (raw)
    power: np.ndarray          # (N,) watts   (raw)
    lat_norm: Normalizer       # std normalizer for log2(latency)
    pow_norm: Normalizer       # std normalizer for log2(power)
    net_norm: Normalizer       # std normalizer for log2(net params)

    @property
    def n(self) -> int:
        return int(self.net_idx.shape[0])

    # encoded views ---------------------------------------------------------
    def net_encoded(self, model: DesignModel, net_idx: Optional[np.ndarray] = None):
        idx = self.net_idx if net_idx is None else net_idx
        vals = model.net_space.values_from_indices(idx)
        return self.net_norm(binary_log2_encode(vals)).astype(np.float32)

    def obj_encoded(self, lat: np.ndarray, pow_: np.ndarray):
        """Objectives on the same scale-free log2 ("binary number") encoding
        as the net params (§6.1 encodes both identically).  Raw metrics span
        5-7 decades on every design model, so std-normalizing them directly
        collapses almost all objectives to ~0 and the conditional G loses
        its conditioning signal."""
        lo = self.lat_norm(binary_log2_encode(np.asarray(lat)[..., None]))
        po = self.pow_norm(binary_log2_encode(np.asarray(pow_)[..., None]))
        return np.concatenate([lo, po], axis=-1).astype(np.float32)


@dataclasses.dataclass
class DSETask:
    """One DSE task batch: networks + the user's objectives `metric <= x`
    (§5).  Row-wise slicing (`take`) and `concat` are what the serve
    micro-batcher uses to coalesce independent in-flight requests into one
    dispatchable batch and to pad it to a pow2 bucket."""

    net_idx: np.ndarray        # (T, n_net_dims)
    lat_obj: np.ndarray        # (T,) seconds
    pow_obj: np.ndarray        # (T,) watts

    def __len__(self) -> int:
        return int(np.asarray(self.net_idx).shape[0])

    def take(self, idx) -> "DSETask":
        """Row gather: idx is any numpy fancy index (ints, slice, bool
        mask).  Repeated indices are allowed — the batcher pads a
        micro-batch to its pow2 bucket by repeating the last row."""
        idx = np.asarray(idx)
        return DSETask(net_idx=np.atleast_2d(self.net_idx[idx]),
                       lat_obj=np.atleast_1d(self.lat_obj[idx]),
                       pow_obj=np.atleast_1d(self.pow_obj[idx]))

    @staticmethod
    def concat(tasks: "Sequence[DSETask]") -> "DSETask":
        """Row-wise concatenation of task batches (coalescing)."""
        assert len(tasks) > 0, "concat of zero task batches"
        return DSETask(
            net_idx=np.concatenate([np.atleast_2d(t.net_idx) for t in tasks]),
            lat_obj=np.concatenate([np.atleast_1d(t.lat_obj) for t in tasks]),
            pow_obj=np.concatenate([np.atleast_1d(t.pow_obj) for t in tasks]),
        )

    @staticmethod
    def single(net_idx: np.ndarray, lat_obj: float, pow_obj: float) -> "DSETask":
        """One request -> a 1-row task batch."""
        return DSETask(net_idx=np.atleast_2d(np.asarray(net_idx)),
                       lat_obj=np.atleast_1d(np.asarray(lat_obj, np.float64)),
                       pow_obj=np.atleast_1d(np.asarray(pow_obj, np.float64)))


def generate_dataset(
    model: DesignModel, n: int, seed: int = 0, oversample: float = 3.0
) -> Dataset:
    """Evenly sample the design space; keep `n` feasible rows."""
    rng = np.random.default_rng(seed)
    net_rows, cfg_rows, lats, pows = [], [], [], []
    got = 0
    while got < n:
        m = int(max(n * oversample, 1024))
        net_idx = model.net_space.sample_indices(rng, m)
        cfg_idx = model.space.sample_indices(rng, m)
        lat, pw = model.evaluate_indices(net_idx, cfg_idx)
        ok = np.isfinite(lat) & np.isfinite(pw)
        net_rows.append(net_idx[ok])
        cfg_rows.append(cfg_idx[ok])
        lats.append(lat[ok])
        pows.append(pw[ok])
        got += int(ok.sum())
    net_idx = np.concatenate(net_rows)[:n]
    cfg_idx = np.concatenate(cfg_rows)[:n]
    lat = np.concatenate(lats)[:n]
    pw = np.concatenate(pows)[:n]

    net_vals = model.net_space.values_from_indices(net_idx)
    return Dataset(
        model_name=model.name,
        net_idx=net_idx,
        cfg_idx=cfg_idx,
        latency=lat,
        power=pw,
        lat_norm=Normalizer.fit(binary_log2_encode(lat[:, None]), center=True),
        pow_norm=Normalizer.fit(binary_log2_encode(pw[:, None]), center=True),
        net_norm=Normalizer.fit(binary_log2_encode(net_vals), center=True),
    )


def generate_tasks(
    model: DesignModel,
    n_tasks: int,
    seed: int = 1,
    slack: tuple = (1.0, 2.5),
) -> DSETask:
    """Sample DSE tasks whose objectives are achievable (there exists at
    least one config meeting them): draw a net + a witness config, evaluate
    it, and relax the witness metrics by a random slack factor in `slack`.
    slack=(1.0, 1.0) yields Pareto-adjacent (hard) objectives (§7.4).
    """
    rng = np.random.default_rng(seed)
    net_rows, lo_rows, po_rows = [], [], []
    got = 0
    while got < n_tasks:
        m = max(n_tasks * 2, 512)
        net_idx = model.net_space.sample_indices(rng, m)
        cfg_idx = model.space.sample_indices(rng, m)
        lat, pw = model.evaluate_indices(net_idx, cfg_idx)
        ok = np.isfinite(lat) & np.isfinite(pw)
        s_l = rng.uniform(slack[0], slack[1], size=m)
        s_p = rng.uniform(slack[0], slack[1], size=m)
        net_rows.append(net_idx[ok])
        lo_rows.append((lat * s_l)[ok])
        po_rows.append((pw * s_p)[ok])
        got += int(ok.sum())
    return DSETask(
        net_idx=np.concatenate(net_rows)[:n_tasks],
        lat_obj=np.concatenate(lo_rows)[:n_tasks],
        pow_obj=np.concatenate(po_rows)[:n_tasks],
    )
