from repro.dataset.generator import Dataset, DSETask, generate_dataset, generate_tasks  # noqa: F401
