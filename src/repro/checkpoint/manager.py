"""Sharded checkpoint manager: atomic, checksummed, keep-N, auto-resume.

Layout:  <dir>/step_<n>/host_<i>.npz + manifest.json (written last — temp
file + ``os.replace`` inside the staging dir, then the whole step dir is
published by a single rename — so a partially-written checkpoint is never
resumable and the previous checkpoint for the same step survives a crash
mid-save).  Each host writes only the leaves (or leaf-shards) it owns; on
this single-host container host_0 holds everything, but the format and the
restore path are multi-host shaped (restore validates the manifest's
host_count and step).

Integrity: the manifest records a crc32 per leaf; ``restore`` and
``verify`` recompute them and raise `CheckpointCorruptionError` (with the
offending file and leaf) on any mismatch or unreadable payload — a
corrupted checkpoint must be *detected at swap time*, never silently
attached as garbage params (the serving tier's corrupted-swap recovery,
exercised by `repro.serve.faults.corrupt_checkpoint` and
`benchmarks/bench_load.py`).

Fault-tolerance contract used by launch/train.py and the serving tier:
  * save(step, tree) never corrupts the previous checkpoint;
  * latest_step() -> most recent step with a valid (parseable) manifest;
  * restore(step, like) -> pytree matching `like`'s structure/dtypes, or
    CheckpointCorruptionError — GANDSE.attach-compatible: `like` may be
    live generator params (only shape/dtype metadata is consulted) and the
    restored tree feeds straight into `GANDSE.attach` / `DSEServer.swap`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
import zlib
from typing import Any, List, Optional

import jax
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint failed integrity validation (checksum mismatch, missing
    or unreadable payload).  Callers recover by falling back to the last
    valid step — never by attaching the damaged tree."""


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    names = [f"leaf_{i}" for i in range(len(leaves))]
    return leaves, names, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    #: retention bound: prune to the newest N steps after every save (0
    #: disables pruning).  Retention is conservative by construction: it
    #: deletes nothing unless the just-saved step verifies (manifest +
    #: checksums), a pruned step is atomically de-listed (rename) before
    #: its payload is deleted, and stray aside/prune dirs left by crashed
    #: saves or prunes are swept on the next save — a long online loop
    #: (`repro.serve.online`) holds steady disk instead of filling it.
    keep_last_n: int = 3
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ---- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _manifest(self, step: int) -> str:
        return os.path.join(self._step_dir(step), "manifest.json")

    def _payload(self, step: int) -> str:
        return os.path.join(self._step_dir(step),
                            f"host_{self.host_index}.npz")

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        leaves, names, _ = _flatten_with_names(tree)
        sdir = self._step_dir(step)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_save_")
        try:
            arrs = {n: np.asarray(l) for n, l in zip(names, leaves)}
            np.savez(os.path.join(tmp, f"host_{self.host_index}.npz"), **arrs)
            manifest = {
                "step": step,
                "time": time.time(),
                "host_count": self.host_count,
                "n_leaves": len(leaves),
                "checksums": {n: _crc(a) for n, a in arrs.items()},
                "extra": extra or {},
            }
            # manifest last, via temp file + os.replace: its presence (and
            # parseability) is what marks the step complete
            mtmp = os.path.join(tmp, ".manifest.json.tmp")
            with open(mtmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(mtmp, os.path.join(tmp, "manifest.json"))
            if os.path.exists(sdir):
                # keep the old step alive until the new one is in place
                # (a crash between these renames leaves the aside copy,
                # invisible to steps(), instead of zero checkpoints)
                aside = os.path.join(self.directory,
                                     f".old_step_{step:09d}")
                shutil.rmtree(aside, ignore_errors=True)
                os.rename(sdir, aside)
                os.rename(tmp, sdir)           # atomic publish
                shutil.rmtree(aside, ignore_errors=True)
            else:
                os.rename(tmp, sdir)           # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc(new_step=step)
        return sdir

    # ---- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if not d.startswith("step_"):
                continue
            mpath = os.path.join(self.directory, d, "manifest.json")
            try:
                with open(mpath) as f:
                    json.load(f)
            except (OSError, ValueError):
                continue               # absent or torn manifest: not resumable
            out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _load_manifest(self, step: int) -> dict:
        with open(self._manifest(step)) as f:
            return json.load(f)

    def verify(self, step: int) -> dict:
        """Validate one step's payload against its manifest checksums
        without building the output tree; returns the manifest.  Raises
        `CheckpointCorruptionError` on any mismatch — the pre-swap gate."""
        manifest = self._load_manifest(step)
        self._verified_arrays(step, manifest)
        return manifest

    def _verified_arrays(self, step: int, manifest: dict) -> dict:
        path = self._payload(step)
        try:
            with np.load(path) as data:
                arrs = {n: data[n] for n in data.files}
        except Exception as e:
            raise CheckpointCorruptionError(
                f"checkpoint step {step}: unreadable payload {path}: "
                f"{e}") from e
        sums = manifest.get("checksums")
        if sums is not None:           # absent on pre-checksum checkpoints
            for n, want in sums.items():
                if n not in arrs:
                    raise CheckpointCorruptionError(
                        f"checkpoint step {step}: leaf '{n}' missing "
                        f"from {path}")
                got = _crc(arrs[n])
                if got != int(want):
                    raise CheckpointCorruptionError(
                        f"checkpoint step {step}: checksum mismatch on "
                        f"leaf '{n}' of {path} (stored {want}, "
                        f"recomputed {got}) — refusing to restore "
                        f"corrupted params")
        return arrs

    def restore(self, step: int, like: Any) -> Any:
        manifest = self._load_manifest(step)
        leaves, names, treedef = _flatten_with_names(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        data = self._verified_arrays(step, manifest)
        new_leaves = []
        for n, l in zip(names, leaves):
            arr = data[n]
            # `like` may be deleted/donated device arrays or
            # ShapeDtypeStructs; only shape/dtype metadata is consulted.
            assert arr.shape == tuple(l.shape), (n, arr.shape, l.shape)
            new_leaves.append(arr.astype(l.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def restore_latest(self, like: Any):
        """(step, tree) of the newest step that passes validation, skipping
        corrupted ones (each raises internally and is passed over), or
        None when no step restores cleanly — the swap-time recovery path:
        a damaged newest checkpoint falls back to the previous good one."""
        for step in reversed(self.steps()):
            try:
                return step, self.restore(step, like)
            except CheckpointCorruptionError:
                continue
        return None

    def restore_extra(self, step: int) -> dict:
        return self._load_manifest(step)["extra"]

    # ---- gc ----------------------------------------------------------------
    def _gc(self, new_step: Optional[int] = None) -> None:
        """``keep_last_n`` retention + stray sweep, run after every save.

        Prunes steps older than the newest ``keep_last_n`` — but only once
        the just-saved step passes ``verify`` (manifest parse + payload
        checksums): if the newest save is torn or already damaged, nothing
        is deleted, so the good history ``restore_latest`` falls back on
        survives.  Then sweeps aside/prune dirs (``.old_step_*``,
        ``.prune_*``) orphaned by a crash mid-save or mid-prune — they are
        invisible to ``steps()`` but used to leak disk forever.
        """
        if new_step is not None:
            try:
                self.verify(new_step)
            except (CheckpointCorruptionError, OSError):
                return      # never prune on the strength of an unverified save
        if self.keep_last_n > 0:
            for s in self.steps()[: -self.keep_last_n]:
                self._remove_step(s)
        for d in os.listdir(self.directory):
            if d.startswith((".old_step_", ".prune_")):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)

    def _remove_step(self, step: int) -> None:
        """Crash-safe prune: rename the step dir aside first (one atomic
        op de-lists it from ``steps()``, so a crash mid-delete can never
        leave a listed step with a half-deleted payload), then delete."""
        doomed = os.path.join(self.directory, f".prune_step_{step:09d}")
        shutil.rmtree(doomed, ignore_errors=True)
        try:
            os.rename(self._step_dir(step), doomed)
        except OSError:
            return          # already gone (earlier crashed prune finished it)
        shutil.rmtree(doomed, ignore_errors=True)
