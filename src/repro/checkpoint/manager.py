"""Sharded checkpoint manager: atomic, keep-N, auto-resume.

Layout:  <dir>/step_<n>/host_<i>.npz + manifest.json (written last, via
atomic rename, so a partially-written checkpoint is never resumable).
Each host writes only the leaves (or leaf-shards) it owns; on this
single-host container host_0 holds everything, but the format and the
restore path are multi-host shaped (restore validates the manifest's
host_count and step).

Fault-tolerance contract used by launch/train.py:
  * save(step, tree) never corrupts the previous checkpoint;
  * latest_step() -> most recent step with a valid manifest;
  * restore(step, like) -> pytree matching `like`'s structure/dtypes.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
from typing import Any, List, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    names = [f"leaf_{i}" for i in range(len(leaves))]
    return leaves, names, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_n: int = 3
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ---- paths -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _manifest(self, step: int) -> str:
        return os.path.join(self._step_dir(step), "manifest.json")

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        leaves, names, _ = _flatten_with_names(tree)
        sdir = self._step_dir(step)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_save_")
        try:
            arrs = {n: np.asarray(l) for n, l in zip(names, leaves)}
            np.savez(os.path.join(tmp, f"host_{self.host_index}.npz"), **arrs)
            manifest = {
                "step": step,
                "time": time.time(),
                "host_count": self.host_count,
                "n_leaves": len(leaves),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(sdir):
                shutil.rmtree(sdir)
            os.rename(tmp, sdir)           # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return sdir

    # ---- restore ---------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any) -> Any:
        with open(self._manifest(step)) as f:
            manifest = json.load(f)
        leaves, names, treedef = _flatten_with_names(like)
        assert manifest["n_leaves"] == len(leaves), "tree structure changed"
        data = np.load(os.path.join(self._step_dir(step),
                                    f"host_{self.host_index}.npz"))
        new_leaves = []
        for n, l in zip(names, leaves):
            arr = data[n]
            # `like` may be deleted/donated device arrays or
            # ShapeDtypeStructs; only shape/dtype metadata is consulted.
            assert arr.shape == tuple(l.shape), (n, arr.shape, l.shape)
            new_leaves.append(arr.astype(l.dtype))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def restore_extra(self, step: int) -> dict:
        with open(self._manifest(step)) as f:
            return json.load(f)["extra"]

    # ---- gc ----------------------------------------------------------------
    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
