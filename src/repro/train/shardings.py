"""Sharding rules for params, optimizer state, activations, and caches.

Strategy (FSDP x TP hybrid, the framework default):
  * weights: the feature/output dim of every projection is sharded over
    the 'model' mesh axis (Megatron TP); the *other* large dim is sharded
    over 'data' (ZeRO/FSDP) so params + Adam moments scale with the full
    chip count.  The 'pod' axis is pure DP (params replicated across
    pods; gradients all-reduced over ('pod','data')).
  * activations: the residual stream saved at layer boundaries (the remat
    save points) is sharded (batch -> ('pod','data'), d_model -> 'model').
  * caches/recurrent state: batch over ('pod','data') when divisible;
    otherwise the sequence dim goes to 'data' (long-context decode with
    global_batch=1) and the head/feature dim to 'model'.

Every rule checks divisibility and falls back to replication, so reduced
smoke configs lower on 1 device with the same code path.
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# mesh context (lets model code request constraints without carrying a mesh)
# ---------------------------------------------------------------------------
_CTX = {"mesh": None, "act_shard": "model"}


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], act_shard: str = "model"):
    """act_shard: how the residual stream's d_model axis is sharded at the
    remat save points — 'model' (tensor-parallel style), 'seq' (sequence
    parallel: shard S over 'model'), or 'none' (replicate)."""
    prev = (_CTX["mesh"], _CTX["act_shard"])
    _CTX["mesh"], _CTX["act_shard"] = mesh, act_shard
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["act_shard"] = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape.get(name, 1) if name in mesh.shape else 1


def norm_axes(axes, mesh: Optional[Mesh] = None):
    """Normalize a PartitionSpec axis entry: drop axes the mesh lacks or
    holds at size 1 (sharding over them is a no-op), and collapse the empty
    result to None.  ``PartitionSpec((), ...)`` is not a valid spec — an
    empty batch-axes tuple used to leak through ``_div`` (vacuously true:
    ``batch % 1 == 0``) and poison ``activation_spec``/``state_spec`` on
    meshes without a 'pod'/'data' axis."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    if mesh is not None:
        axes = tuple(a for a in axes if axis_size(mesh, a) > 1)
    return axes if axes else None


def _div(dim: int, mesh: Mesh, name) -> bool:
    """True iff `name` names real (present, size > 1) mesh axes whose
    product divides `dim` — absent axes no longer "divide" via their
    size-1 fallback, so rules fall back to replication instead of
    emitting specs that reference axes the mesh does not have."""
    name = norm_axes(name, mesh)
    return name is not None and dim % axis_size(mesh, name) == 0


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def constrain(x, spec: P):
    """with_sharding_constraint iff a mesh context is active."""
    mesh = current_mesh()
    if mesh is None or len(mesh.devices.flatten()) == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def activation_spec(mesh: Mesh, batch: int, d_model: int,
                    seq: Optional[int] = None) -> P:
    """(B, S, D) residual-stream spec (policy set by use_mesh act_shard)."""
    ba = norm_axes(batch_axes(mesh), mesh)
    b_ax = ba if _div(batch, mesh, ba) \
        else (norm_axes("data", mesh) if _div(batch, mesh, "data") else None)
    policy = _CTX["act_shard"]
    if policy == "seq" and seq is not None and _div(seq, mesh, "model"):
        return P(b_ax, "model", None)
    if policy == "model" and _div(d_model, mesh, "model"):
        return P(b_ax, None, "model")
    return P(b_ax, None, None)


# ---------------------------------------------------------------------------
# parameter rules (matched on the leaf's key name; rank-agnostic — a
# leading stacked-layer axis simply pads the spec with None on the left)
# ---------------------------------------------------------------------------
_LAST = {"wq", "wkv", "w_gate", "w_up", "in_proj", "wz", "wqkv", "wx",
         "dt_w", "conv_w", "lm_head", "router"}
_PENULT = {"wo", "w_down", "out_proj", "x_proj", "A_log", "rh"}
_VOCAB_FIRST = {"table", "pos_embed"}       # embed: vocab over 'model'
_VEC_MODEL = {"D_skip", "dt_bias"}          # 1-D inner-dim vectors


def _param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
                fsdp: bool = True) -> P:
    name = path.split("/")[-1]
    rank = len(shape)
    spec = [None] * rank

    def put(dim: int, ax: str):
        if ax == "data" and not fsdp:
            return
        if 0 <= dim < rank and _div(shape[dim], mesh, ax) and spec[dim] is None:
            spec[dim] = ax

    if name in ("w_gate", "w_up", "w_down") and rank >= 3:
        # MoE expert tensors (E, D, F) / (E, F, D): expert parallelism when
        # the expert count divides the 'model' axis, else TP on the F dim.
        e_dim = rank - 3
        if _div(shape[e_dim], mesh, "model"):
            put(e_dim, "model")
            put(rank - 1 if name != "w_down" else rank - 2, "data")
        elif name in _LAST:
            put(rank - 1, "model")
            put(rank - 2, "data")
        else:
            put(rank - 2, "model")
            put(rank - 1, "data")
    elif name in _LAST and rank >= 2:
        put(rank - 1, "model")
        put(rank - 2, "data")                      # FSDP on the other big dim
    elif name in _PENULT and rank >= 2:
        put(rank - 2, "model")
        put(rank - 1, "data")
    elif name in _VOCAB_FIRST and rank >= 2:
        put(rank - 2, "model")
        put(rank - 1, "data")
    elif name in _VEC_MODEL and rank >= 1:
        put(rank - 1, "model")
    elif rank >= 2 and min(shape[-2:]) >= 256:     # any other big matrix: FSDP
        put(rank - 1, "data")
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """Pytree of PartitionSpec matching `params`.  fsdp=False keeps weights
    replicated across 'data' (pure DP + TP; trades HBM for fewer
    all-gathers — a §Perf knob)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(_path_str(path), leaf.shape, mesh,
                                       fsdp=fsdp), params
    )


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


# ---------------------------------------------------------------------------
# decode/cache state rules (structural, shape-driven)
# ---------------------------------------------------------------------------
def state_spec(shape: Tuple[int, ...], mesh: Mesh, batch: int) -> P:
    """Greedy structural spec for a decode-state leaf.

    Convention (see models/base.spec_state_init): leaves are stacked with a
    leading layer axis, then batch.  (L, B, S, H, D) KV caches, (L, B, H,
    dh, dh) matrix memories, (L, B, D, N) SSM states, (L, B) scalars.
    """
    rank = len(shape)
    spec = [None] * rank
    if rank < 2:
        return P(*spec)
    used_model = False
    ba = norm_axes(batch_axes(mesh), mesh)
    data_used = False
    if shape[1] == batch and _div(batch, mesh, ba):
        spec[1] = ba
        data_used = True
    elif shape[1] == batch and _div(batch, mesh, "data"):
        spec[1] = norm_axes("data", mesh)
        data_used = True
    # remaining dims, largest first: give 'data' (if free) to the largest
    # (the 500k sequence axis), 'model' to the next largest divisible.
    order = sorted(range(2, rank), key=lambda i: -shape[i])
    for i in order:
        if not data_used and shape[i] >= 1024 and _div(shape[i], mesh, "data"):
            spec[i] = "data"
            data_used = True
        elif not used_model and _div(shape[i], mesh, "model") and shape[i] > 1:
            spec[i] = "model"
            used_model = True
    return P(*spec)


def state_specs(states, mesh: Mesh, batch: int):
    return jax.tree.map(lambda leaf: state_spec(leaf.shape, mesh, batch), states)
