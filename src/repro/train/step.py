"""Step factories: train_step / prefill_step / decode_step per (arch x
shape), plus ``build_case`` which packages the jittable function, its
ShapeDtypeStruct inputs, and NamedShardings for the dry-run, benchmarks,
and the real launchers.

No device memory is allocated here: params/opt/caches are built with
``jax.eval_shape`` so the 33B-param architectures lower on this CPU
container.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import Shape
from repro.models import base as MB
from repro.optim import adamw, apply_updates
from repro.train import shardings as SH


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def next_token_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross entropy; logits (B, S, V) may be vocab-sharded."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# train / serve step factories
# ---------------------------------------------------------------------------
def make_train_step(m: MB.ModelCfg, *, lr: float = 3e-4, remat: bool = True,
                    mesh: Optional[Mesh] = None, microbatches: int = 1,
                    act_shard: str = "model",
                    grad_compress=None) -> Tuple[Callable, Any]:
    """Returns (train_step, optimizer).  train_step(params, opt, batch) ->
    (params, opt, metrics).

    microbatches > 1 enables gradient accumulation: the global batch is
    split along axis 0 and scanned, dividing activation memory by the
    microbatch count at the cost of re-running the FSDP weight all-gathers
    per microbatch (the §Perf memory<->collective trade-off knob).
    `grad_compress` optionally wraps gradients (see optim/compress.py)."""
    optim = adamw(lr, weight_decay=0.1, clip_norm=1.0)

    def loss_fn(params, batch):
        enc_out = None
        if m.enc_segments is not None:
            enc_out = MB.encode(params, m, batch["frames"], remat=remat)
        logits = MB.forward(params, m, batch["tokens"],
                            positions=batch.get("positions"),
                            enc_out=enc_out, remat=remat)
        return next_token_loss(logits, batch["labels"])

    def grads_of(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            if x.ndim >= 2 and x.shape[0] == 3:      # vlm positions (3, B, S)
                return jnp.moveaxis(
                    x.reshape(3, microbatches, -1, *x.shape[2:]), 1, 0)
            return x.reshape(microbatches, -1, *x.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}

        def body(acc, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            acc_loss, acc_g = acc
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, g_sum), _ = jax.lax.scan(body, zero, micro)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(params, opt_state, batch):
        with SH.use_mesh(mesh, act_shard=act_shard):
            loss, grads = grads_of(params, batch)
            if grad_compress is not None:
                grads = grad_compress(grads)
            updates, opt_state = optim.update(grads, opt_state, params)
            params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return train_step, optim


def make_prefill_step(m: MB.ModelCfg, *, mesh: Optional[Mesh] = None) -> Callable:
    def prefill_step(params, batch):
        with SH.use_mesh(mesh):
            enc_out = None
            if m.enc_segments is not None:
                enc_out = MB.encode(params, m, batch["frames"])
            logits = MB.forward(params, m, batch["tokens"],
                                positions=batch.get("positions"),
                                enc_out=enc_out)
        return logits[:, -1]

    return prefill_step


def make_decode_step(m: MB.ModelCfg, *, mesh: Optional[Mesh] = None) -> Callable:
    def decode_step(params, token, pos, states, enc_out=None, start=None):
        with SH.use_mesh(mesh):
            logits, states = MB.decode_step(params, m, token, pos, states,
                                            enc_out=enc_out, start=start)
        return logits, states

    return decode_step


# ---------------------------------------------------------------------------
# shape-struct builders (no allocation)
# ---------------------------------------------------------------------------
WHISPER_DEC_LEN = 448


def batch_structs(m: MB.ModelCfg, shape: Shape, dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if m.enc_segments is not None:
        # audio: cell seq_len = encoder frames; decoder native length
        sd = min(WHISPER_DEC_LEN, s)
        return {
            "frames": jax.ShapeDtypeStruct((b, s, m.d_model), dtype),
            "tokens": jax.ShapeDtypeStruct((b, sd), i32),
            "labels": jax.ShapeDtypeStruct((b, sd), i32),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }
    if m.family == "vlm":
        out["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    return out


def batch_specs(m: MB.ModelCfg, shape: Shape, mesh: Mesh) -> Dict[str, P]:
    ba = SH.batch_axes(mesh)
    b = shape.global_batch
    b_ax = ba if b % SH.axis_size(mesh, ba) == 0 else (
        "data" if b % SH.axis_size(mesh, "data") == 0 else None)
    if m.enc_segments is not None:
        return {"frames": P(b_ax, None, None), "tokens": P(b_ax, None),
                "labels": P(b_ax, None)}
    out = {"tokens": P(b_ax, None), "labels": P(b_ax, None)}
    if m.family == "vlm":
        out["positions"] = P(None, b_ax, None)
    return out


def param_structs(m: MB.ModelCfg, dtype=jnp.bfloat16):
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda r: MB.init_params(r, m, dtype), rng)


def state_structs(params_struct, m: MB.ModelCfg, batch: int, cache_len: int,
                  dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda p: MB.init_decode_state(p, m, batch, cache_len, dtype),
        params_struct)


# ---------------------------------------------------------------------------
# the packaged case: everything the dry-run / benches need for one cell
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Case:
    name: str
    fn: Callable                 # jittable
    args: Tuple[Any, ...]        # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()


def _shardings_of(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_case(m: MB.ModelCfg, shape: Shape, mesh: Mesh, *,
               dtype=jnp.bfloat16, lr: float = 3e-4,
               remat: bool = True, microbatches: int = 1,
               fsdp: bool = True, act_shard: str = "model") -> Case:
    """One (arch x shape) dry-run cell on `mesh`.  The keyword knobs
    (microbatches / remat / fsdp / act_shard) are the §Perf hillclimb
    dimensions."""
    p_struct = param_structs(m, dtype)
    p_specs = SH.param_specs(p_struct, mesh, fsdp=fsdp)
    p_sh = _shardings_of(p_specs, mesh)

    if shape.kind == "train":
        step, optim = make_train_step(m, lr=lr, remat=remat, mesh=mesh,
                                      microbatches=microbatches,
                                      act_shard=act_shard)
        opt_struct = jax.eval_shape(optim.init, p_struct)
        from repro.optim.adamw import AdamState
        opt_sh = AdamState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh)
        b_struct = batch_structs(m, shape, dtype)
        b_sh = _shardings_of(batch_specs(m, shape, mesh), mesh)
        return Case(
            name=f"{m.name}:{shape.name}",
            fn=step,
            args=(p_struct, opt_struct, b_struct),
            in_shardings=(p_sh, opt_sh, b_sh),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        step = make_prefill_step(m, mesh=mesh)
        b_struct = batch_structs(m, shape, dtype)
        if "labels" in b_struct:
            del b_struct["labels"]
        specs = batch_specs(m, shape, mesh)
        specs.pop("labels", None)
        b_sh = _shardings_of(specs, mesh)
        return Case(f"{m.name}:{shape.name}", step, (p_struct, b_struct),
                    (p_sh, b_sh))

    # decode: one new token against a cache of seq_len
    b = shape.global_batch
    step = make_decode_step(m, mesh=mesh)
    st_struct = state_structs(p_struct, m, b, shape.seq_len, dtype)
    st_specs = SH.state_specs(st_struct, mesh, b)
    st_sh = _shardings_of(st_specs, mesh)
    ba = SH.batch_axes(mesh)
    b_ax = ba if b % SH.axis_size(mesh, ba) == 0 else (
        "data" if b % SH.axis_size(mesh, "data") == 0 else None)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(b_ax, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    args = [p_struct, tok, pos, st_struct]
    shs = [p_sh, tok_sh, pos_sh, st_sh]
    if m.enc_segments is not None:
        enc = jax.ShapeDtypeStruct((b, m.max_enc_len, m.d_model), dtype)
        args.append(enc)
        shs.append(NamedSharding(mesh, P(b_ax, None, None)))
    return Case(f"{m.name}:{shape.name}", step, tuple(args), tuple(shs),
                donate_argnums=(3,))
