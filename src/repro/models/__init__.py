from repro.models import base, builders  # noqa: F401
