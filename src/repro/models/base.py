"""Unified model builder: every assigned architecture is a stack of
*segments*, each segment a ``lax.scan`` over `repeats` copies of a short
periodic *layer pattern* (list of LayerSpecs).

  * uniform archs (qwen3, mixtral, ...): one segment, pattern length 1
  * gemma3 (5 local : 1 global): pattern [local x5, global], repeats 4,
    plus a tail segment of 2 local layers
  * xlstm (mLSTM:sLSTM 7:1): pattern [mlstm x7, slstm], repeats 6
  * hymba: pattern length 1 with a parallel SSM branch in the block

Scanning over repeats keeps the HLO size (and 512-device compile time)
flat in depth; the periodic pattern is unrolled inside the scan body so
heterogeneous layers still share one loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.nn import blocks as B
from repro.nn import layers as L
from repro.nn import xlstm as X
from repro.nn import ssm as S


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """kind: dense | mlstm | slstm (cfg.n_experts / ssm_state select MoE /
    hymba inside the dense block)."""

    kind: str
    cfg: B.BlockCfg


@dataclasses.dataclass(frozen=True)
class Segment:
    repeats: int
    pattern: Tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return self.repeats * len(self.pattern)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                        # dense | moe | vlm | audio | ssm | hybrid
    d_model: int
    vocab: int
    segments: Tuple[Segment, ...]
    tied_embeddings: bool = True
    # enc-dec (whisper): encoder segments; None for decoder-only models
    enc_segments: Optional[Tuple[Segment, ...]] = None
    enc_positions: str = "learned"     # whisper uses learned/sinusoidal abs pos
    max_enc_len: int = 1500
    sub_quadratic: bool = False        # eligible for long_500k
    notes: str = ""

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)


# ---------------------------------------------------------------------------
# per-spec init/apply/decode dispatch
# ---------------------------------------------------------------------------
def spec_init(rng, spec: LayerSpec, dtype=jnp.float32):
    if spec.kind == "dense":
        return B.block_init(rng, spec.cfg, dtype)
    if spec.kind == "mlstm":
        return X.mlstm_init(rng, spec.cfg.d_model, spec.cfg.n_heads, dtype)
    if spec.kind == "slstm":
        return X.slstm_init(rng, spec.cfg.d_model, spec.cfg.n_heads, dtype)
    if spec.kind == "enc":
        return B.enc_block_init(rng, spec.cfg, dtype)
    if spec.kind == "dec":
        return B.dec_block_init(rng, spec.cfg, dtype)
    raise ValueError(spec.kind)


def spec_apply(params, x, spec: LayerSpec, positions, enc_out=None):
    if spec.kind == "dense":
        return B.block_apply(params, x, spec.cfg, positions)
    if spec.kind == "mlstm":
        y, _ = X.mlstm_apply(params, x, spec.cfg.n_heads)
        return x + y
    if spec.kind == "slstm":
        y, _ = X.slstm_apply(params, x, spec.cfg.n_heads)
        return x + y
    if spec.kind == "enc":
        return B.enc_block_apply(params, x, spec.cfg, positions)
    if spec.kind == "dec":
        return B.dec_block_apply(params, x, enc_out, spec.cfg, positions)
    raise ValueError(spec.kind)


def spec_state_init(spec: LayerSpec, batch: int, cache_len: int,
                    dtype=jnp.float32) -> Any:
    """Decode-state pytree for one layer (KV cache / recurrent state)."""
    cfg = spec.cfg
    if spec.kind in ("dense", "dec"):
        span = cache_len if cfg.window is None else min(cfg.window, cache_len)
        kv = (jnp.zeros((batch, span, cfg.n_kv, cfg.dh), dtype),
              jnp.zeros((batch, span, cfg.n_kv, cfg.dh), dtype))
        st = {"kv": kv, "len": jnp.zeros((), jnp.int32)}
        if cfg.ssm_state:
            st["ssm"] = None  # filled by model init (needs params' shapes)
        return st
    if spec.kind == "mlstm":
        dh = cfg.d_model // cfg.n_heads
        return (jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
                jnp.zeros((batch, cfg.n_heads, dh), jnp.float32),
                jnp.full((batch, cfg.n_heads), -1e30, jnp.float32))
    if spec.kind == "slstm":
        d = cfg.d_model
        z = jnp.zeros((batch, d), jnp.float32)
        return (z, z + 1e-6, jnp.full((batch, d), -1e30, jnp.float32), z)
    raise ValueError(spec.kind)


def spec_decode(params, x1, spec: LayerSpec, pos, state, enc_out=None,
                start=None):
    cfg = spec.cfg
    if spec.kind == "dense":
        ring = cfg.window is not None
        return B.block_decode(params, x1, cfg, pos, state, ring=ring,
                              start=start)
    if spec.kind == "dec":
        return B.dec_block_decode(params, x1, enc_out, cfg, pos, state,
                                  start=start)
    if spec.kind == "mlstm":
        y, st = X.mlstm_apply(params, x1, cfg.n_heads, state=state)
        return x1 + y, st
    if spec.kind == "slstm":
        y, st = X.slstm_apply(params, x1, cfg.n_heads, state=state)
        return x1 + y, st
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# whole-model init / forward / decode
# ---------------------------------------------------------------------------
def _stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _segment_init(rng, seg: Segment, dtype):
    """Per-spec stacked params: list over pattern of (repeats, ...) stacks."""
    out = []
    for si, spec in enumerate(seg.pattern):
        reps = [
            spec_init(jax.random.fold_in(rng, si * 10007 + r), spec, dtype)
            for r in range(seg.repeats)
        ]
        out.append(_stack(reps))
    return out


def init_params(rng, m: ModelCfg, dtype=jnp.float32) -> Dict[str, Any]:
    r_embed, r_body, r_head, r_enc = jax.random.split(rng, 4)
    p: Dict[str, Any] = {
        "embed": L.embed_init(r_embed, m.vocab, m.d_model, dtype),
        "segments": [
            _segment_init(jax.random.fold_in(r_body, i), seg, dtype)
            for i, seg in enumerate(m.segments)
        ],
        "ln_f": L.rmsnorm_init(m.d_model, dtype),
    }
    if not m.tied_embeddings:
        p["lm_head"] = (
            jax.random.normal(r_head, (m.d_model, m.vocab), jnp.float32)
            * (1.0 / m.d_model) ** 0.5
        ).astype(dtype)
    if m.enc_segments is not None:
        p["encoder"] = {
            "segments": [
                _segment_init(jax.random.fold_in(r_enc, i), seg, dtype)
                for i, seg in enumerate(m.enc_segments)
            ],
            "pos_embed": (jax.random.normal(
                jax.random.fold_in(r_enc, 999), (m.max_enc_len, m.d_model),
                jnp.float32) * 0.02).astype(dtype),
            "ln_f": L.layernorm_init(m.d_model, dtype),
        }
    return p


def _run_segments(segments_params, segs: Tuple[Segment, ...], x, positions,
                  enc_out=None, remat: bool = False):
    from repro.train import shardings as SH

    def _constrain(xc):
        mesh = SH.current_mesh()
        if mesh is None:
            return xc
        return SH.constrain(
            xc, SH.activation_spec(mesh, xc.shape[0], xc.shape[-1],
                                   seq=xc.shape[1]))

    for seg_p, seg in zip(segments_params, segs):
        def body(xc, layer_params, _seg=seg):
            for spec, sp in zip(_seg.pattern, layer_params):
                xc = spec_apply(sp, xc, spec, positions, enc_out=enc_out)
            return _constrain(xc), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if seg.repeats == 1:
            x, _ = body(x, [jax.tree.map(lambda a: a[0], sp) for sp in seg_p])
        else:
            x, _ = jax.lax.scan(body, x, seg_p)
    return x


def encode(params, m: ModelCfg, frames: jnp.ndarray, remat: bool = False):
    """Whisper encoder over precomputed (stub) frame embeddings
    (B, S_enc, D)."""
    enc = params["encoder"]
    se = frames.shape[1]
    pos_tab = enc["pos_embed"]
    if se > pos_tab.shape[0]:          # extend cyclically for oversize stubs
        reps = -(-se // pos_tab.shape[0])
        pos_tab = jnp.tile(pos_tab, (reps, 1))
    x = frames + pos_tab[None, :se]
    positions = jnp.broadcast_to(jnp.arange(se)[None], frames.shape[:2])
    x = _run_segments(enc["segments"], m.enc_segments, x, positions, remat=remat)
    return L.layernorm_apply(enc["ln_f"], x)


def forward(params, m: ModelCfg, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None,
            enc_out: Optional[jnp.ndarray] = None,
            remat: bool = False) -> jnp.ndarray:
    """tokens (B, S) -> logits (B, S, V).  positions defaults to arange;
    pass (3, B, S) for M-RoPE archs."""
    x = L.embed_apply(params["embed"], tokens)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
    x = _run_segments(params["segments"], m.segments, x, positions,
                      enc_out=enc_out, remat=remat)
    x = L.rmsnorm_apply(params["ln_f"], x)
    if m.tied_embeddings:
        return L.embed_logits(params["embed"], x)
    return x @ params["lm_head"]


def init_decode_state(params, m: ModelCfg, batch: int, cache_len: int,
                      dtype=jnp.float32):
    """Stacked per-segment decode states mirroring the param stacks."""
    states = []
    for seg in m.segments:
        seg_states = []
        for spec in seg.pattern:
            st = spec_state_init(spec, batch, cache_len, dtype)
            if isinstance(st, dict) and "ssm" in st and st["ssm"] is None:
                st["ssm"] = S.ssm_decode_init(
                    _ssm_params_proto(params, m, spec), batch)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (seg.repeats,) + a.shape), st)
            seg_states.append(stacked)
        states.append(seg_states)
    return states


def _ssm_params_proto(params, m: ModelCfg, spec: LayerSpec):
    """Find one layer's ssm params to size the decode state."""
    for seg_p, seg in zip(params["segments"], m.segments):
        for sp, s in zip(seg_p, seg.pattern):
            if s.kind == "dense" and s.cfg.ssm_state:
                return jax.tree.map(lambda a: a[0], sp["ssm"])
    raise ValueError("no ssm layer")


def decode_step(params, m: ModelCfg, token: jnp.ndarray, pos: jnp.ndarray,
                states, enc_out: Optional[jnp.ndarray] = None, start=None):
    """One-token decode.  token (B, 1) int32; pos scalar int32 (absolute
    position).  start: optional (B,) per-lane first valid KV position —
    the stale-cache mask a continuous-batching engine passes when a batch
    lane has been reused for a new request (every attention layer shares
    one timeline, so one vector serves all layers).  Returns
    (logits (B, 1, V), new states)."""
    x = L.embed_apply(params["embed"], token)
    pos_b = jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (token.shape[0], 1))
    new_states = []
    for seg_p, seg, seg_st in zip(params["segments"], m.segments, states):
        def body(xc, per_layer, _seg=seg):
            layer_params, layer_state = per_layer
            new_layer_state = []
            for spec, sp, st in zip(_seg.pattern, layer_params, layer_state):
                xc, st = spec_decode(sp, xc, spec, pos_b, st, enc_out=enc_out,
                                     start=start)
                new_layer_state.append(st)
            return xc, new_layer_state

        if seg.repeats == 1:
            take0 = lambda tree: jax.tree.map(lambda a: a[0], tree)
            x, st = body(x, (list(map(take0, seg_p)), list(map(take0, seg_st))))
            new_states.append([jax.tree.map(lambda a: a[None], s) for s in st])
        else:
            x, st = jax.lax.scan(body, x, (seg_p, seg_st))
            new_states.append(st)
    x = L.rmsnorm_apply(params["ln_f"], x)
    logits = (L.embed_logits(params["embed"], x) if m.tied_embeddings
              else x @ params["lm_head"])
    return logits, new_states


def param_count(params) -> int:
    import numpy as np
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
