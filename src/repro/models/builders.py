"""Helpers that assemble ModelCfg objects for the assigned architectures."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.models.base import LayerSpec, ModelCfg, Segment
from repro.nn.blocks import BlockCfg


def _dense_spec(d, h, kv, dff, *, head_dim=0, qk_norm=False, window=None,
                theta=10000.0, n_experts=0, top_k=2, ssm_state=0, mrope=None):
    return LayerSpec(
        "dense",
        BlockCfg(d_model=d, n_heads=h, n_kv=kv, d_ff=dff, head_dim=head_dim,
                 qk_norm=qk_norm, window=window, rope_theta=theta,
                 n_experts=n_experts, top_k=top_k, ssm_state=ssm_state,
                 mrope_sections=mrope),
    )


def decoder_arch(
    name: str, family: str, n_layers: int, d_model: int, n_heads: int,
    n_kv: int, d_ff: int, vocab: int, *,
    head_dim: int = 0, qk_norm: bool = False, window: Optional[int] = None,
    n_experts: int = 0, top_k: int = 2, ssm_state: int = 0,
    mrope: Optional[Tuple[int, int, int]] = None, tied: bool = True,
    theta: float = 10000.0, sub_quadratic: bool = False, notes: str = "",
) -> ModelCfg:
    spec = _dense_spec(d_model, n_heads, n_kv, d_ff, head_dim=head_dim,
                       qk_norm=qk_norm, window=window, theta=theta,
                       n_experts=n_experts, top_k=top_k, ssm_state=ssm_state,
                       mrope=mrope)
    return ModelCfg(name=name, family=family, d_model=d_model, vocab=vocab,
                    segments=(Segment(n_layers, (spec,)),),
                    tied_embeddings=tied, sub_quadratic=sub_quadratic,
                    notes=notes)


def local_global_arch(
    name: str, family: str, n_layers: int, d_model: int, n_heads: int,
    n_kv: int, d_ff: int, vocab: int, *, head_dim: int = 0,
    local_window: int = 1024, locals_per_global: int = 5,
    tied: bool = True, theta: float = 10000.0, notes: str = "",
) -> ModelCfg:
    """Gemma-3 style L:1 local:global interleave; tail layers stay local."""
    loc = _dense_spec(d_model, n_heads, n_kv, d_ff, head_dim=head_dim,
                      window=local_window, theta=theta)
    glob = _dense_spec(d_model, n_heads, n_kv, d_ff, head_dim=head_dim,
                       window=None, theta=theta)
    period = locals_per_global + 1
    reps, tail = divmod(n_layers, period)
    segs = [Segment(reps, tuple([loc] * locals_per_global + [glob]))]
    if tail:
        segs.append(Segment(tail, (loc,)))
    return ModelCfg(name=name, family=family, d_model=d_model, vocab=vocab,
                    segments=tuple(segs), tied_embeddings=tied,
                    sub_quadratic=True, notes=notes)


def sandwich_arch(
    name: str, family: str, n_layers: int, d_model: int, n_heads: int,
    n_kv: int, d_ff: int, vocab: int, *, head_dim: int = 0,
    local_window: int = 1024, ssm_state: int = 16, n_globals: int = 3,
    tied: bool = True, notes: str = "",
) -> ModelCfg:
    """Hymba-style: global full-attn at first/middle/last layers, sliding-
    window everywhere else; every layer has the parallel SSM branch."""
    loc = _dense_spec(d_model, n_heads, n_kv, d_ff, head_dim=head_dim,
                      window=local_window, ssm_state=ssm_state)
    glob = _dense_spec(d_model, n_heads, n_kv, d_ff, head_dim=head_dim,
                       window=None, ssm_state=ssm_state)
    mid = n_layers - n_globals
    first = mid // 2
    segs = (
        Segment(1, (glob,)),
        Segment(first, (loc,)),
        Segment(1, (glob,)),
        Segment(mid - first, (loc,)),
        Segment(1, (glob,)),
    )
    assert sum(s.n_layers for s in segs) == n_layers
    return ModelCfg(name=name, family=family, d_model=d_model, vocab=vocab,
                    segments=segs, tied_embeddings=tied, sub_quadratic=True,
                    notes=notes)


def xlstm_arch(
    name: str, n_layers: int, d_model: int, n_heads: int, vocab: int, *,
    slstm_every: int = 8, tied: bool = True, notes: str = "",
) -> ModelCfg:
    """mLSTM:sLSTM = (slstm_every-1):1 periodic stack (d_ff = 0: the blocks
    carry their own projections)."""
    cfg = BlockCfg(d_model=d_model, n_heads=n_heads, n_kv=n_heads, d_ff=0)
    m = LayerSpec("mlstm", cfg)
    s = LayerSpec("slstm", cfg)
    reps, tail = divmod(n_layers, slstm_every)
    segs = [Segment(reps, tuple([m] * (slstm_every - 1) + [s]))]
    if tail:
        segs.append(Segment(tail, (m,)))
    return ModelCfg(name=name, family="ssm", d_model=d_model, vocab=vocab,
                    segments=tuple(segs), tied_embeddings=tied,
                    sub_quadratic=True, notes=notes)


def encdec_arch(
    name: str, n_enc: int, n_dec: int, d_model: int, n_heads: int,
    n_kv: int, d_ff: int, vocab: int, *, max_enc_len: int = 1500,
    tied: bool = True, notes: str = "",
) -> ModelCfg:
    """Whisper-style encoder-decoder.  The conv audio frontend is a STUB:
    input_specs() provides precomputed frame embeddings (B, S_enc, D)."""
    enc = LayerSpec("enc", BlockCfg(d_model=d_model, n_heads=n_heads,
                                    n_kv=n_kv, d_ff=d_ff))
    dec = LayerSpec("dec", BlockCfg(d_model=d_model, n_heads=n_heads,
                                    n_kv=n_kv, d_ff=d_ff))
    return ModelCfg(name=name, family="audio", d_model=d_model, vocab=vocab,
                    segments=(Segment(n_dec, (dec,)),),
                    enc_segments=(Segment(n_enc, (enc,)),),
                    max_enc_len=max_enc_len, tied_embeddings=tied,
                    notes=notes)
