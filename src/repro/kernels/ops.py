"""Public jit'd entry points for the Pallas kernels.

On TPU the Pallas path is used; elsewhere (this CPU container) the pure-XLA
fallback keeps semantics identical, and ``interpret=True`` forces the
Pallas kernel body to execute in Python for validation.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import dispatch as _dispatch
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return _dispatch.on_tpu()


def fused_dense_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                     *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """relu(x @ w + b); x may have leading batch dims (flattened to M).
    Thin alias over ``dispatch.dense`` (the single dispatch point)."""
    return _dispatch.dense(x, w, b, relu=True, interpret=bool(interpret))


def fused_dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                *, interpret: Optional[bool] = None) -> jnp.ndarray:
    return _dispatch.dense(x, w, b, relu=False, interpret=bool(interpret))


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """(B, H, S, D) x (B, Hkv, S, D)^2 -> (B, H, S, D)."""
    if interpret or (interpret is None and _on_tpu()):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, interpret=bool(interpret))
    return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)
