"""Pallas TPU kernels: fused dense+bias+ReLU and the whole-MLP megakernel
(the GAN's MLP hot-spot, forward AND backward).

The GANDSE G/D networks are deep ReLU MLPs (11-14 layers x 2048); on TPU
the hot loop is `y = relu(x @ w + b)` repeated per layer.  Three kernels
cover it:

- ``fused_dense`` — one layer, bias+ReLU fused into the matmul epilogue.
  Differentiable: a ``custom_vjp`` backs it with Pallas backward kernels
  (dx = g @ Wᵀ, dW = xᵀ @ g, db = Σ_M g, where g = dy·[y > 0] folds the
  ReLU mask into the same accumulate-in-VMEM tiling as the forward), so
  Algorithm 1's jitted/scanned train step runs fused end to end.
- ``fused_mlp`` — the layer-chained forward megakernel for inference-only
  paths: the hidden activations ping-pong between two VMEM scratch
  buffers across the layer grid axis instead of round-tripping through
  HBM once per layer.  Also differentiable (its VJP re-runs the layer
  chain through ``fused_dense``'s kernels).

Tiling (shared by forward and backward): grid (rows/bm, cols/bn, red/bk)
with the reduction axis innermost (sequential), accumulating into a VMEM
f32 scratch tile; on the last reduction step the epilogue (bias+ReLU, or
the output cast) runs and the tile is written once.  VMEM working set =
bm*bk + bk*bn + bm*bn (+ bn bias) floats; the default (256, 512, 512)
tiles use ~1.6 MB — far below the ~16 MB/core budget and MXU-aligned.
Operands whose dims do not divide the block are zero-padded up to the
block multiple (and outputs sliced back), so a prime/odd dim can never
force a whole-dim block past the VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 512


def _pick(block: int, dim: int) -> int:
    """Block size for `dim`: the requested block, shrunk to the next power
    of two >= dim when the dim is smaller.  Never returns `dim` itself for
    an awkward (prime/odd) dim — the operand is zero-padded up to a block
    multiple instead, so the VMEM working set is bounded by the requested
    block size, not by the shape."""
    return min(block, max(8, 1 << (max(int(dim), 1) - 1).bit_length()))


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    return jnp.pad(a, ((0, pr), (0, pc))) if pr or pc else a


def _pad1(a: jnp.ndarray, n: int) -> jnp.ndarray:
    p = n - a.shape[0]
    return jnp.pad(a, (0, p)) if p else a


# ---------------------------------------------------------------------------
# forward: y = [relu](x @ w + b)
# ---------------------------------------------------------------------------
def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, relu: bool):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


def _forward(x, w, b, *, relu: bool, bm: int, bk: int, bn: int, interpret: bool):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bk, bn = _pick(bm, m), _pick(bk, k), _pick(bn, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp, wp, bp = _pad2(x, mp, kp), _pad2(w, kp, np_), _pad1(b, np_)
    n_k = kp // bk

    grid = (mp // bm, np_ // bn, n_k)
    y = pl.pallas_call(
        functools.partial(_fused_dense_kernel, n_k=n_k, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return y[:m, :n] if (mp, np_) != (m, n) else y


# ---------------------------------------------------------------------------
# backward: dx = g @ wᵀ, dw = xᵀ @ g, db = Σ_M g  (g = dy·[y > 0])
# ---------------------------------------------------------------------------
def _dx_kernel(dy_ref, y_ref, w_ref, o_ref, acc_ref, *, n_n: int, relu: bool):
    n_step = pl.program_id(2)

    @pl.when(n_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = dy_ref[...].astype(jnp.float32)
    if relu:
        g = g * (y_ref[...].astype(jnp.float32) > 0.0)
    # (bm, bn) x (bk, bn) contracted over the shared N axis -> (bm, bk)
    acc_ref[...] += jax.lax.dot_general(
        g, w_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n_step == n_n - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dw_db_kernel(x_ref, dy_ref, y_ref, dw_ref, db_ref, accw_ref, accb_ref,
                  *, n_m: int, relu: bool):
    k_blk = pl.program_id(1)
    m_step = pl.program_id(2)

    @pl.when(m_step == 0)
    def _init_w():
        accw_ref[...] = jnp.zeros_like(accw_ref)

    @pl.when((m_step == 0) & (k_blk == 0))
    def _init_b():
        accb_ref[...] = jnp.zeros_like(accb_ref)

    g = dy_ref[...].astype(jnp.float32)
    if relu:
        g = g * (y_ref[...].astype(jnp.float32) > 0.0)
    # (bm, bk) x (bm, bn) contracted over the shared M axis -> (bk, bn)
    accw_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), g,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # db needs one full M sweep; take the k_blk == 0 sweep (g is identical
    # across k blocks) and let the scratch carry the sum to the write below
    @pl.when(k_blk == 0)
    def _acc_b():
        accb_ref[...] += jnp.sum(g, axis=0, keepdims=True)

    @pl.when(m_step == n_m - 1)
    def _epilogue():
        dw_ref[...] = accw_ref[...].astype(dw_ref.dtype)
        db_ref[...] = accb_ref[...].astype(db_ref.dtype)


def _backward(x, w, dy, y, *, relu: bool, bm: int, bk: int, bn: int,
              interpret: bool):
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = _pick(bm, m), _pick(bk, k), _pick(bn, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp, wp = _pad2(x, mp, kp), _pad2(w, kp, np_)
    dyp, yp = _pad2(dy, mp, np_), _pad2(y, mp, np_)
    n_m, n_k, n_n = mp // bm, kp // bk, np_ // bn

    dx = pl.pallas_call(
        functools.partial(_dx_kernel, n_n=n_n, relu=relu),
        grid=(n_m, n_k, n_n),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, kk, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, kk, j: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, kk, j: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, kk, j: (i, kk)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(dyp, yp, wp)

    dw, db = pl.pallas_call(
        functools.partial(_dw_db_kernel, n_m=n_m, relu=relu),
        grid=(n_n, n_k, n_m),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, kk, mm: (mm, kk)),
            pl.BlockSpec((bm, bn), lambda j, kk, mm: (mm, j)),
            pl.BlockSpec((bm, bn), lambda j, kk, mm: (mm, j)),
        ],
        out_specs=[
            pl.BlockSpec((bk, bn), lambda j, kk, mm: (kk, j)),
            pl.BlockSpec((1, bn), lambda j, kk, mm: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, np_), w.dtype),
            jax.ShapeDtypeStruct((1, np_), dy.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        interpret=interpret,
    )(xp, dyp, yp)

    return dx[:m, :k], dw[:k, :n], db[0, :n]


@functools.lru_cache(maxsize=None)
def _fused_dense_vjp(relu: bool, bm: int, bk: int, bn: int, interpret: bool):
    """custom_vjp'd (x, w, b) -> y closure over the static kernel config.

    Residuals are (x, w, y): the ReLU mask is recomputed from the saved
    output (y > 0), so the backward never re-runs the forward matmul.
    """

    @jax.custom_vjp
    def fd(x, w, b):
        return _forward(x, w, b, relu=relu, bm=bm, bk=bk, bn=bn,
                        interpret=interpret)

    def fwd(x, w, b):
        y = fd(x, w, b)
        return y, (x, w, y)

    def bwd(res, dy):
        x, w, y = res
        dx, dw, db = _backward(x, w, dy, y, relu=relu, bm=bm, bk=bk, bn=bn,
                               interpret=interpret)
        return dx, dw.astype(w.dtype), db.astype(x.dtype)

    fd.defvjp(fwd, bwd)
    return fd


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bk", "bn", "interpret"))
def fused_dense(
    x: jnp.ndarray,                 # (M, K)
    w: jnp.ndarray,                 # (K, N)
    b: jnp.ndarray,                 # (N,)
    *,
    relu: bool = True,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    """[relu](x @ w + b), differentiable (Pallas forward AND backward)."""
    return _fused_dense_vjp(relu, bm, bk, bn, interpret)(x, w, b)


# ---------------------------------------------------------------------------
# whole-MLP layer-chained forward megakernel
# ---------------------------------------------------------------------------
def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, h0_ref, h1_ref, *, n_layers: int):
    l = pl.program_id(1)
    j = pl.program_id(2)
    bn = o_ref.shape[-1]

    parity = jax.lax.rem(l, 2)
    # activations ping-pong between the two VMEM buffers; layer 0 reads the
    # HBM input block instead (the h buffers are uninitialized then — the
    # where() discards them)
    h_prev = jnp.where(parity == 0, h0_ref[...], h1_ref[...])
    h_in = jnp.where(l == 0, x_ref[...].astype(jnp.float32), h_prev)

    y = jnp.dot(h_in, w_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = y + b_ref[...].astype(jnp.float32)
    y = jnp.where(l == n_layers - 1, y, jnp.maximum(y, 0.0))

    col = pl.multiple_of(j * bn, bn)

    @pl.when(parity == 0)
    def _to_h1():
        h1_ref[:, pl.ds(col, bn)] = y

    @pl.when(parity == 1)
    def _to_h0():
        h0_ref[:, pl.ds(col, bn)] = y

    @pl.when(l == n_layers - 1)
    def _out():
        o_ref[...] = y.astype(o_ref.dtype)


def _mlp_forward(x, ws, bs, *, bm: int, bn: int, interpret: bool):
    m, d_in = x.shape
    d_out = ws[-1].shape[1]
    n_layers = len(ws)
    dims = {d_in, d_out}
    for w in ws:
        dims.update(w.shape)
    h = max(dims)
    bn = _pick(bn, h)
    bm = _pick(bm, m)
    h = _round_up(h, bn)
    mp = _round_up(m, bm)

    # every layer padded onto the (h, h) square: zero rows/cols keep the
    # chain exact (relu(0·x + 0) = 0 rides along and is sliced off at the end)
    w_stack = jnp.stack([_pad2(w, h, h) for w in ws])           # (L, h, h)
    b_stack = jnp.stack([_pad1(b, h) for b in bs])              # (L, h)
    xp = _pad2(x, mp, h)

    grid = (mp // bm, n_layers, h // bn)
    y = pl.pallas_call(
        functools.partial(_mlp_kernel, n_layers=n_layers),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda i, l, j: (i, 0)),
            pl.BlockSpec((1, h, bn), lambda i, l, j: (l, 0, j)),
            pl.BlockSpec((1, bn), lambda i, l, j: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, l, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, h), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, h), jnp.float32),
            pltpu.VMEM((bm, h), jnp.float32),
        ],
        interpret=interpret,
    )(xp, w_stack, b_stack)
    return y[:m, :d_out]


def _layer_chain(x, ws, bs, *, bm, bk, bn, interpret):
    """The megakernel's semantics as a chain of fused_dense layers (hidden
    ReLU, linear head) — the recompute used by its VJP."""
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = fused_dense(x, w, b, relu=i < len(ws) - 1, bm=bm, bk=bk, bn=bn,
                        interpret=interpret)
    return x


@functools.lru_cache(maxsize=None)
def _fused_mlp_vjp(bm: int, bk: int, bn: int, interpret: bool):
    @jax.custom_vjp
    def fm(x, ws, bs):
        return _mlp_forward(x, ws, bs, bm=bm, bn=bn, interpret=interpret)

    def fwd(x, ws, bs):
        return fm(x, ws, bs), (x, ws, bs)

    def bwd(res, dy):
        # inference-first kernel: the backward re-runs the layer chain
        # through fused_dense (whose own VJP is Pallas) rather than
        # shipping a second megakernel.  For non-f32 dtypes this is the
        # gradient of the per-layer-rounded chain, not of the forward's
        # all-f32 VMEM chain (training paths use mlp_apply, which IS the
        # per-layer chain, so the pairing is exact where grads matter)
        x, ws, bs = res
        _, vjp = jax.vjp(
            lambda x_, ws_, bs_: _layer_chain(x_, ws_, bs_, bm=bm, bk=bk,
                                              bn=bn, interpret=interpret),
            x, ws, bs)
        return vjp(dy)

    fm.defvjp(fwd, bwd)
    return fm


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def fused_mlp(
    x: jnp.ndarray,                 # (M, D_in)
    ws: Tuple[jnp.ndarray, ...],    # per-layer (K_l, N_l)
    bs: Tuple[jnp.ndarray, ...],    # per-layer (N_l,)
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    """Whole-MLP forward (hidden ReLU, linear head) as ONE pallas_call:
    activations stay in VMEM across the layer grid axis (two ping-pong
    scratch buffers) instead of an HBM round-trip per layer.  VMEM working
    set: x block (bm·h) + weight slab (h·bn) + 2 activation buffers (bm·h)
    + out (bm·bn) floats, h = padded max layer width — ~10.5 MB at the
    paper's 2048-wide nets with the default (256, 512) blocks."""
    assert len(ws) == len(bs) and len(ws) >= 1
    return _fused_mlp_vjp(bm, bk, bn, interpret)(x, tuple(ws), tuple(bs))
