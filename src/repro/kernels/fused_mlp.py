"""Pallas TPU kernel: fused dense + bias + ReLU (the GAN's MLP hot-spot).

The GANDSE G/D networks are deep ReLU MLPs (11-14 layers x 2048); on TPU
the hot loop is `y = relu(x @ w + b)` repeated per layer.  Fusing bias+ReLU
into the matmul epilogue removes one HBM round-trip of the (M, N)
activation per layer — the layer becomes purely MXU-bound.

Tiling: grid (M/bm, N/bn, K/bk); the K axis is the innermost (sequential)
grid dimension, accumulating into a VMEM f32 scratch tile.  On the last K
step the bias is added, ReLU applied, and the tile written out once.
VMEM working set = bm*bk + bk*bn + bm*bn (+ bn bias) floats; the default
(256, 512, 512) tiles use ~1.6 MB — far below the ~16 MB/core budget and
MXU-aligned (every dim a multiple of 128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BK = 512
DEFAULT_BN = 512


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, n_k: int, relu: bool):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


def _pick(block: int, dim: int) -> int:
    """Largest divisor of `dim` that is <= block (prefers the block itself)."""
    if dim % block == 0:
        return block
    b = block
    while b > 1 and dim % b:
        b //= 2
    return b if dim % b == 0 else dim


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bk", "bn", "interpret"))
def fused_dense(
    x: jnp.ndarray,                 # (M, K)
    w: jnp.ndarray,                 # (K, N)
    b: jnp.ndarray,                 # (N,)
    *,
    relu: bool = True,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bk, bn = _pick(bm, m), _pick(bk, k), _pick(bn, n)
    n_k = k // bk

    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fused_dense_kernel, n_k=n_k, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
