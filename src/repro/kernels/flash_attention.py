"""Pallas TPU kernel: flash attention (GQA + causal + sliding window).

TPU adaptation of the flash algorithm: the online-softmax accumulator
lives in VMEM scratch; the grid is (batch, q_head, q_block, kv_block) with
the kv axis innermost (sequential), so each (b, h, i) q-tile streams the
KV blocks through VMEM once.  GQA is expressed in the BlockSpec index map
(kv head = q head // group) — no KV replication in HBM.

Block shapes default to (bq, d) = (256, Dh) and bk = 256: VMEM working set
= bq*d (q) + 2*bk*d (kv) + bq*d (acc) + small m/l vectors ≈ 0.75 MB for
Dh=128 — MXU-aligned and far under the VMEM budget, leaving room for
double buffering of the KV stream.

For sliding-window attention, out-of-band KV blocks are skipped with
``pl.when`` — the MXU work for a (bq, bk) tile is only issued when the
band [qpos-window, qpos] intersects the block, making the kernel's compute
truly sub-quadratic in sequence length.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    n_k: int,
    bq: int,
    bk: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    scale: float,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Is this KV block inside the (causal/window) band of this q block?
    q_lo = q_offset + i * bq                # first absolute q position
    q_hi = q_lo + bq - 1                    # last absolute q position
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_lo <= q_hi)
    if window is not None:
        relevant = jnp.logical_and(relevant, k_hi > q_lo - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                        # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == n_k - 1)
    def _epilogue():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pick(block: int, dim: int) -> int:
    if dim % block == 0:
        return block
    b = block
    while b > 1 and dim % b:
        b //= 2
    return b if dim % b == 0 else dim


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
# the head dim d rides in VMEM unblocked by design (softmax needs the whole
# row); callers pad heads to a pow2 lane width before entry, so d is never
# an awkward runtime value.
# lint: disable=pallas-blockspec
def flash_attention(
    q: jnp.ndarray,              # (B, H, Sq, D)
    k: jnp.ndarray,              # (B, Hkv, Sk, D)
    v: jnp.ndarray,              # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    bq = _pick(bq, sq)
    bk = _pick(bk, sk)
    n_q = sq // bq
    n_k = sk // bk

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel,
        n_k=n_k, bq=bq, bk=bk, causal=causal, window=window,
        q_offset=q_offset, scale=1.0 / (d ** 0.5),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, i, j: (bb, hh, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, i, j, g=g: (bb, hh // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, i, j, g=g: (bb, hh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, hh, i, j: (bb, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
