"""THE backend-aware dispatch point for the fused-MLP fast path.

Every consumer of the fused kernels (nn/layers, core/gan, core/explorer,
baselines/mlp, serve) routes through this module, so the decision "Pallas
or jnp reference?" lives in exactly one place:

- TPU backend       -> Pallas kernels (compiled);
- CPU / GPU         -> pure-jnp reference (identical semantics);
- ``use_fused``     -> overrides the backend default: ``False`` forces the
  jnp route even on TPU, ``True`` requests fusion (still a no-op off-TPU,
  where the compiled Pallas path does not exist); ``None`` = backend auto;
- ``interpret=True`` (or the ``force_interpret()`` test hook) -> the
  Pallas kernel body executes in interpret mode regardless of backend, so
  CPU CI validates the exact kernel code TPU runs.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.kernels import fused_mlp as _fm
from repro.kernels import ref as _ref

#: test hook: when True, every dispatch runs the Pallas kernels in
#: interpret mode (flip via force_interpret(); traces must happen inside
#: the context — already-jitted closures keep the route they traced with)
_FORCE_INTERPRET = False


@contextlib.contextmanager
def force_interpret(enable: bool = True):
    """Route every dispatch through the Pallas kernels in interpret mode —
    the CPU test hook that drives the *kernel* code through jitted
    consumers (train step, explorer forward) without a TPU."""
    global _FORCE_INTERPRET
    old, _FORCE_INTERPRET = _FORCE_INTERPRET, enable
    try:
        yield
    finally:
        _FORCE_INTERPRET = old


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_enabled(use_fused: Optional[bool]) -> bool:
    """The dispatch rule: explicit flag wins, None means backend auto."""
    return on_tpu() if use_fused is None else bool(use_fused)


def _route(use_fused: Optional[bool], interpret: bool):
    """-> (use_pallas, interpret) after applying the rule above.

    Precedence: an explicit ``use_fused=False`` beats the global
    ``force_interpret()`` hook (a consumer pinned to the jnp reference
    stays there — that is the documented "False forces jnp" contract, and
    it keeps hook-driven parity tests honest), while a *call-site*
    ``interpret=True`` still wins (it is an explicit request to run the
    kernel body, the per-call test API)."""
    if interpret:
        return True, True
    if use_fused is False:
        return False, False
    if _FORCE_INTERPRET:
        return True, True
    return fused_enabled(use_fused) and on_tpu(), False


def kernel_route_active(use_fused: Optional[bool] = None,
                        interpret: bool = False) -> bool:
    """True when ``dense``/``mlp_chain`` with these args would run the
    Pallas kernels (compiled or interpret) rather than the jnp reference —
    the one predicate callers gate on, so it can never drift from the
    route the dispatchers actually take."""
    return _route(use_fused, interpret)[0]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
          relu: bool = True, use_fused: Optional[bool] = None,
          interpret: bool = False) -> jnp.ndarray:
    """[relu](x @ w + b); x may carry leading batch dims (flattened to M).
    Differentiable on both routes (the Pallas route via its custom_vjp)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    pallas, interp = _route(use_fused, interpret)
    if pallas:
        y = _fm.fused_dense(x2, w, b, relu=relu, interpret=interp)
    elif relu:
        y = _ref.fused_dense_relu(x2, w, b)
    else:
        y = _ref.fused_dense(x2, w, b)
    return y.reshape(*lead, w.shape[-1])


def mlp_chain(layers: List[dict], x: jnp.ndarray, *,
              use_fused: Optional[bool] = None,
              interpret: bool = False) -> jnp.ndarray:
    """Whole-MLP forward (hidden ReLU, linear head) from a
    ``mlp_init``-style layer list.  The fused route is the layer-chained
    megakernel (activations never leave VMEM between layers) — the
    inference fast path; the reference route is the plain jnp loop."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    pallas, interp = _route(use_fused, interpret)
    if pallas:
        ws = tuple(p["w"] for p in layers)
        bs = tuple(p["b"] for p in layers)
        y = _fm.fused_mlp(x2, ws, bs, interpret=interp)
    else:
        y = x2
        for p in layers[:-1]:
            y = jax.nn.relu(y @ p["w"] + p["b"])
        y = y @ layers[-1]["w"] + layers[-1]["b"]
    return y.reshape(*lead, layers[-1]["w"].shape[-1])
