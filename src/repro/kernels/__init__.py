"""Pallas TPU kernels for the framework's compute hot-spots.

- fused_mlp: dense+bias+ReLU epilogue fusion (GANDSE G/D MLP layers),
  differentiable via custom_vjp Pallas backward kernels, plus the
  whole-MLP layer-chained forward megakernel for inference paths
- flash_attention: GQA/causal/sliding-window flash attention (LM layers)

Each kernel ships with ``ref.py`` (pure-jnp oracle) and is validated in
interpret mode on CPU; ``dispatch.py`` is the single backend-aware
routing point (TPU -> Pallas, CPU/GPU -> jnp reference, ``interpret``
and ``force_interpret()`` for tests); ``ops.py`` keeps thin jit wrappers.
"""
from repro.kernels import dispatch, ops  # noqa: F401
