"""Pallas TPU kernels for the framework's compute hot-spots.

- fused_mlp: dense+bias+ReLU epilogue fusion (GANDSE G/D MLP layers)
- flash_attention: GQA/causal/sliding-window flash attention (LM layers)

Each kernel ships with ``ref.py`` (pure-jnp oracle) and is validated in
interpret mode on CPU; ``ops.py`` holds the dispatching jit wrappers.
"""
from repro.kernels import ops  # noqa: F401
