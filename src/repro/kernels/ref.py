"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition of the kernel with no tiling
or hardware concerns; tests assert_allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def fused_dense_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """relu(x @ w + b) in f32 accumulation."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    return jnp.maximum(y, 0.0).astype(x.dtype)


def fused_dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x @ w + b in f32 accumulation (no activation, output head)."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype)


def fused_mlp(x: jnp.ndarray, ws, bs) -> jnp.ndarray:
    """Whole-MLP chain (hidden ReLU, linear head) in f32 accumulation —
    the oracle for the layer-chained megakernel."""
    y = x.astype(jnp.float32)
    for i, (w, b) in enumerate(zip(ws, bs)):
        y = jnp.dot(y, w.astype(jnp.float32)) + b.astype(jnp.float32)
        if i < len(ws) - 1:
            y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def flash_attention(
    q: jnp.ndarray,            # (B, H, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Sk, D)
    v: jnp.ndarray,            # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Unblocked GQA attention; softmax in f32. Returns (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    scores = jnp.einsum(
        "bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)


def moe_dispatch_ffn(
    x: jnp.ndarray,            # (T, Dm) tokens
    w_gate: jnp.ndarray,       # (E, Dm, Dff)  (SwiGLU gate proj)
    w_up: jnp.ndarray,         # (E, Dm, Dff)
    w_down: jnp.ndarray,       # (E, Dff, Dm)
    expert_idx: jnp.ndarray,   # (T, K) int
    expert_w: jnp.ndarray,     # (T, K) float routing weights
) -> jnp.ndarray:
    """Dense-gather MoE oracle: every token runs through its K experts."""
    t, dm = x.shape
    kk = expert_idx.shape[1]
    xf = x.astype(jnp.float32)

    def one(tok, eidx, ew):
        def per_k(e):
            g = jax.nn.silu(tok @ w_gate[e].astype(jnp.float32))
            u = tok @ w_up[e].astype(jnp.float32)
            return (g * u) @ w_down[e].astype(jnp.float32)

        outs = jax.vmap(per_k)(eidx)           # (K, Dm)
        return jnp.sum(outs * ew[:, None], axis=0)

    out = jax.vmap(one)(xf, expert_idx, expert_w.astype(jnp.float32))
    return out.astype(x.dtype)
