"""Large-MLP DSE baseline (paper §7.1.4, AIRCHITECT-style, Fig. 3(a)).

A single MLP regresses from (net params, objectives) to the training-set
configurations with plain per-group cross entropy — no satisfaction mask,
no discriminator.  Parameter count is matched to the full GAN (G + D) by
construction ("much larger than the G in the GAN").  The design selector
(Algorithm 2) is applied to its thresholded outputs, as in the paper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core.explorer import ExplorerConfig, enumerate_candidates
from repro.core.selector import select
from repro.core.dse_api import DSEResult
from repro.core.train import encode_batch
from repro.dataset.generator import Dataset, DSETask, generate_dataset
from repro.design_models.base import DesignModel
from repro.nn import layers as L
from repro.optim import adam, apply_updates


@dataclasses.dataclass
class LargeMLP:
    model: DesignModel
    hidden_layers: int = 16           # parameter-matched to G+D
    neurons: int = 2048
    lr: float = 2e-5
    batch_size: int = 1024
    noise_dim: int = 8
    explorer_cfg: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)

    def __post_init__(self):
        self.ds: Optional[Dataset] = None
        self.params = None
        space = self.model.space

        @jax.jit
        def fwd(params, net_enc, obj_enc, noise):
            x = jnp.concatenate([net_enc, obj_enc, noise], axis=-1)
            logits = L.mlp_apply(params, x)
            probs = [jax.nn.softmax(g, -1) for g in space.split_groups(logits)]
            return jnp.concatenate(probs, axis=-1)

        self._fwd = fwd

    def n_params(self) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(self.params))

    def train(self, n_data: int, iters: int, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        self.ds = ds if ds is not None else generate_dataset(self.model, n_data, seed=seed)
        space = self.model.space
        n_in = self.model.net_space.n_dims + 2 + self.noise_dim
        rng = jax.random.PRNGKey(seed)
        self.params = L.mlp_init(rng, n_in, [self.neurons] * self.hidden_layers,
                                 space.onehot_width)
        optim = adam(self.lr)
        opt = optim.init(self.params)

        def loss_fn(params, batch, noise):
            probs = self._fwd(params, batch["net_enc"], batch["obj_enc"], noise)
            return jnp.mean(G.grouped_cross_entropy(space, batch["cfg_onehot"], probs))

        @jax.jit
        def step(params, opt, batch, rng):
            rng, nrng = jax.random.split(rng)
            noise = jax.random.uniform(nrng, (batch["net_enc"].shape[0], self.noise_dim),
                                       jnp.float32, -0.1, 0.1)
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, noise)
            upd, opt = optim.update(grads, opt)
            return apply_updates(params, upd), opt, rng, loss

        np_rng = np.random.default_rng(seed)
        n = self.ds.n
        bs = min(self.batch_size, n)
        for it in range(iters):
            perm = np_rng.permutation(n)
            for b0 in range(0, n - bs + 1, bs):
                batch = {k: jnp.asarray(v) for k, v in
                         encode_batch(self.model, self.ds, perm[b0:b0 + bs]).items()}
                self.params, opt, rng, loss = step(self.params, opt, batch, rng)
            if log_every and it % log_every == 0:
                print(f"[large_mlp] iter={it} loss={float(loss):.4f}")
        return self

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        t0 = time.time()
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded(np.atleast_1d(lat_obj), np.atleast_1d(pow_obj))
        noise = jnp.zeros((1, self.noise_dim), jnp.float32)
        probs = np.asarray(self._fwd(self.params, jnp.asarray(net_enc),
                                     jnp.asarray(obj_enc), noise))[0]
        cands = enumerate_candidates(self.model.space, probs,
                                     self.explorer_cfg.prob_threshold,
                                     self.explorer_cfg.max_candidates)
        sel = select(self.model, net_idx, cands, lat_obj, pow_obj)
        return DSEResult(sel, float(lat_obj), float(pow_obj), time.time() - t0)

    def explore_tasks(self, tasks: DSETask, seed: int = 0):
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                             seed=seed + i)
                for i in range(tasks.net_idx.shape[0])]
