"""Large-MLP DSE baseline (paper §7.1.4, AIRCHITECT-style, Fig. 3(a)).

A single MLP regresses from (net params, objectives) to the training-set
configurations with plain per-group cross entropy — no satisfaction mask,
no discriminator.  Parameter count is matched to the full GAN (G + D) by
construction ("much larger than the G in the GAN").  The design selector
(Algorithm 2) is applied to its thresholded outputs, as in the paper.

Exploration mirrors the GANDSE explorer exactly: the MLP receives the same
noise input as G (§7.1.4), task t averages ``noise_samples`` forward passes
drawn from PRNGKey(seed + t), and ``explore_tasks`` serves the whole batch
device-resident (vmapped forward -> on-device candidate enumeration ->
batched Algorithm 2), falling back to the sequential host loop for models
without a jnp oracle.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core import shard
from repro.core.explorer import (ExplorerConfig, enumerate_candidates,
                                 enumerate_candidates_batch,
                                 flatten_task_draws, task_keys)
from repro.core.fused_select import fused_select_batch
from repro.core.selector import select, select_batch
from repro.core.dse_api import DSEResult, row_seeds
from repro.core.train import encode_batch
from repro.dataset.generator import Dataset, DSETask, generate_dataset
from repro.design_models.base import DesignModel
from repro.nn import layers as L
from repro.optim import adam, apply_updates


@functools.lru_cache(maxsize=None)
def _cached_fwd(space, noise_dim: int, use_fused: Optional[bool] = None,
                chained: bool = None):
    """Jitted MLP inference, cached on (space, noise_dim, use_fused) like
    the explorer's G forward: retrains / new LargeMLP instances never
    recompile.

    ``fwd``: plain batch forward (training loss path; per-layer fused
    dense on the fused route so the loss stays differentiable).
    ``fwd_mean``: per-task noise-averaged forward for exploration — task t
    averages n_samples draws from fold_in(keys[t], s), the same streams
    whether tasks run one at a time or batched (the batched-vs-sequential
    parity contract, identical to the Explorer's).  On the fused route
    (``chained`` None = dispatch auto) the draws flatten into one row
    batch through the layer-chained megakernel, mirroring the Explorer.
    """
    from repro.kernels import dispatch as D
    if chained is None:
        chained = D.fused_enabled(use_fused) and D.on_tpu()

    def _probs_logits(logits):
        probs = [jax.nn.softmax(g, -1) for g in space.split_groups(logits)]
        return jnp.concatenate(probs, axis=-1)

    def _probs(params, net_enc, obj_enc, noise):
        x = jnp.concatenate([net_enc, obj_enc, noise], axis=-1)
        return _probs_logits(L.mlp_apply(params, x, use_fused=use_fused))

    fwd = jax.jit(_probs)

    def noise_fn(key, s):
        return G.sample_noise_dim(jax.random.fold_in(key, s), 1, noise_dim)[0]

    @functools.partial(jax.jit, static_argnames="n_samples")
    def fwd_mean(params, net_enc, obj_enc, keys, n_samples):
        if chained:
            t = net_enc.shape[0]
            net_r, obj_r, noise_r = flatten_task_draws(
                net_enc, obj_enc, keys, n_samples, noise_fn)
            x = jnp.concatenate([net_r, obj_r, noise_r], axis=-1)
            probs = _probs_logits(
                L.mlp_apply_chained(params, x, use_fused=use_fused))
            return jnp.mean(probs.reshape(t, n_samples, -1), axis=1)

        def one_task(net, obj, key):
            def one(s):
                noise = G.sample_noise_dim(jax.random.fold_in(key, s), 1,
                                           noise_dim)
                return _probs(params, net[None], obj[None], noise)[0]
            return jnp.mean(jax.vmap(one)(jnp.arange(n_samples)), axis=0)

        return jax.vmap(one_task)(net_enc, obj_enc, keys)

    return fwd, fwd_mean


@dataclasses.dataclass
class LargeMLP:
    model: DesignModel
    hidden_layers: int = 16           # parameter-matched to G+D
    neurons: int = 2048
    lr: float = 2e-5
    batch_size: int = 1024
    noise_dim: int = 8
    explorer_cfg: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)
    #: Pallas fused-MLP path (kernels/dispatch.py rule): None = backend auto
    use_fused: Optional[bool] = None

    method_name = "LargeMLP"

    def __post_init__(self):
        self.ds: Optional[Dataset] = None
        self.params = None
        self._fwd, self._fwd_mean = _cached_fwd(self.model.space,
                                                self.noise_dim,
                                                self.use_fused)

    def set_use_fused(self, use_fused: Optional[bool]) -> "LargeMLP":
        """Flip the fused-MLP dispatch (serving-layer override hook);
        refreshes the cached jitted forwards for the new route."""
        self.use_fused = use_fused
        self._fwd, self._fwd_mean = _cached_fwd(self.model.space,
                                                self.noise_dim, use_fused)
        return self

    def n_params(self) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(self.params))

    def init_params(self, seed: int = 0):
        """Fresh params for this architecture — the single definition of the
        input width (net params + 2 objective channels + noise), shared by
        `train` and the bench/serving `attach` path."""
        n_in = self.model.net_space.n_dims + 2 + self.noise_dim
        return L.mlp_init(jax.random.PRNGKey(seed), n_in,
                          [self.neurons] * self.hidden_layers,
                          self.model.space.onehot_width)

    def train(self, n_data: int, iters: int, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        self.ds = ds if ds is not None else generate_dataset(self.model, n_data, seed=seed)
        space = self.model.space
        rng = jax.random.PRNGKey(seed)
        self.params = self.init_params(seed)
        optim = adam(self.lr)
        opt = optim.init(self.params)

        def loss_fn(params, batch, noise):
            probs = self._fwd(params, batch["net_enc"], batch["obj_enc"], noise)
            return jnp.mean(G.grouped_cross_entropy(space, batch["cfg_onehot"], probs))

        @jax.jit
        def step(params, opt, batch, rng):
            rng, nrng = jax.random.split(rng)
            noise = G.sample_noise_dim(nrng, batch["net_enc"].shape[0],
                                       self.noise_dim)
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, noise)
            upd, opt = optim.update(grads, opt)
            return apply_updates(params, upd), opt, rng, loss

        np_rng = np.random.default_rng(seed)
        n = self.ds.n
        bs = min(self.batch_size, n)
        for it in range(iters):
            perm = np_rng.permutation(n)
            for b0 in range(0, n - bs + 1, bs):
                batch = {k: jnp.asarray(v) for k, v in
                         encode_batch(self.model, self.ds, perm[b0:b0 + bs]).items()}
                self.params, opt, rng, loss = step(self.params, opt, batch, rng)
            if log_every and it % log_every == 0:
                print(f"[large_mlp] iter={it} loss={float(loss):.4f}")
        return self

    def attach(self, ds: Dataset, params) -> "LargeMLP":
        """Serving entry (mirrors GANDSE.attach): wire a dataset (for its
        normalizers) and trained params without retraining."""
        self.ds = ds
        self.params = params
        return self

    def generator_probs_device(self, net_idx: np.ndarray, lat_obj, pow_obj,
                               seed: int = 0) -> jnp.ndarray:
        """Vmapped noise-averaged forward: (T, onehot_width) device probs.
        Task row t draws from PRNGKey(seed + t) (host-int64 sum), bitwise
        equal to a single-task call with seed + t."""
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded(np.atleast_1d(lat_obj),
                                      np.atleast_1d(pow_obj))
        keys = task_keys(seed, net_enc.shape[0])
        # task-sharded over the active mesh (no-op without one): put_sharded
        # is a drop-in for jnp.asarray, see repro.core.shard
        return self._fwd_mean(self.params, shard.put_sharded(net_enc),
                              shard.put_sharded(obj_enc),
                              shard.put_sharded(keys),
                              n_samples=self.explorer_cfg.noise_samples)

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        t0 = time.time()
        probs = np.asarray(
            self.generator_probs_device(net_idx, lat_obj, pow_obj, seed))[0]
        cands = enumerate_candidates(self.model.space, probs,
                                     self.explorer_cfg.prob_threshold,
                                     self.explorer_cfg.max_candidates)
        sel = select(self.model, net_idx, cands, lat_obj, pow_obj)
        return DSEResult(sel, float(lat_obj), float(pow_obj), time.time() - t0)

    def explore_batch(self, tasks: DSETask, seed: int = 0) -> List[DSEResult]:
        """Batched device-resident exploration, same structure (and parity
        contract) as ``GANDSE.explore_batch``: vmapped forward -> fused
        streaming enumerate/score/select (``batch_route="dense"`` on the
        explorer config keeps the reference materialized route).
        dse_seconds is the amortized per-task wall-clock."""
        n_tasks = int(tasks.net_idx.shape[0])
        if n_tasks == 0:
            return []
        if not self.model.has_jax_oracle:
            return self._explore_seq(tasks, seed)
        t0 = time.time()
        # pad to the active mesh's shard multiple (GANDSE.explore_batch
        # rule: padded lanes computed and discarded, parity bit-exact)
        seeds = row_seeds(seed, n_tasks)
        tasks_p, seeds, n_real = shard.pad_tasks(tasks, seeds)
        probs = self.generator_probs_device(tasks_p.net_idx, tasks_p.lat_obj,
                                            tasks_p.pow_obj, seeds)
        if self.explorer_cfg.batch_route == "dense":
            cand, valid, counts = enumerate_candidates_batch(
                self.model.space, probs, self.explorer_cfg.prob_threshold,
                self.explorer_cfg.max_candidates)
            sels = select_batch(self.model, tasks_p.net_idx, cand, valid,
                                counts, tasks_p.lat_obj, tasks_p.pow_obj)
        else:
            sels = fused_select_batch(
                self.model, tasks_p.net_idx, probs,
                self.explorer_cfg.prob_threshold,
                self.explorer_cfg.max_candidates,
                tasks_p.lat_obj, tasks_p.pow_obj,
                tile=self.explorer_cfg.select_tile)
        per_task = (time.time() - t0) / n_real
        return [
            DSEResult(sel, float(tasks.lat_obj[i]), float(tasks.pow_obj[i]),
                      per_task)
            for i, sel in enumerate(sels[:n_real])
        ]

    def explore_tasks(self, tasks: DSETask, seed: int = 0,
                      batched: Optional[bool] = None) -> List[DSEResult]:
        if batched is None:
            batched = self.model.has_jax_oracle
        if batched:
            return self.explore_batch(tasks, seed=seed)
        return self._explore_seq(tasks, seed)

    def _explore_seq(self, tasks: DSETask, seed) -> List[DSEResult]:
        seeds = row_seeds(seed, tasks.net_idx.shape[0])
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                             seed=int(seeds[i]))
                for i in range(tasks.net_idx.shape[0])]
