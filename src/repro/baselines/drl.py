"""Deep-reinforcement-learning DSE baseline (paper §7.1.4, ConfuciuX-style).

Policy-gradient (REINFORCE with a moving baseline).  The state is the
current (network parameters, objectives, configuration); actions set one
configuration dimension to one of its choices; the reward is the decrease
in objective violation, with a bonus when the state satisfies the
objectives.  An MLP actor is trained offline over dataset-derived tasks;
at DSE time a short greedy rollout is run and the best visited
configuration is returned (iterative DSE, but with a learned policy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selector import Selection
from repro.core.dse_api import DSEResult
from repro.core.train import encode_batch
from repro.dataset.generator import Dataset, DSETask, generate_dataset
from repro.design_models.base import DesignModel
from repro.nn import layers as L
from repro.optim import adam, apply_updates


def _violation(lat, pw, lo, po):
    lat = np.nan_to_num(lat, posinf=1e9)
    pw = np.nan_to_num(pw, posinf=1e9)
    return np.maximum(0.0, (lat - lo) / lo) + np.maximum(0.0, (pw - po) / po)


@dataclasses.dataclass
class PolicyGradientDRL:
    model: DesignModel
    hidden_layers: int = 3
    neurons: int = 256
    lr: float = 1e-4
    rollout_len: int = 16
    batch_tasks: int = 64
    gamma: float = 0.95
    sat_bonus: float = 2.0
    seed: int = 0

    def __post_init__(self):
        self.ds: Optional[Dataset] = None
        self.params = None
        space = self.model.space
        self._n_actions = space.onehot_width  # action = (dim, choice) flattened

        @jax.jit
        def policy_logits(params, net_enc, obj_enc, cfg_onehot):
            x = jnp.concatenate([net_enc, obj_enc, cfg_onehot], axis=-1)
            return L.mlp_apply(params, x)

        self._logits = policy_logits

    # --- helpers -------------------------------------------------------------
    def _apply_actions(self, cfg_idx: np.ndarray, actions: np.ndarray) -> np.ndarray:
        """actions: flat indices into onehot_width -> set (dim, choice)."""
        space = self.model.space
        out = cfg_idx.copy()
        off = 0
        for di, d in enumerate(space.dims):
            in_group = (actions >= off) & (actions < off + d.n)
            out[in_group, di] = actions[in_group] - off
            off += d.n
        return out

    def train(self, n_data: int, iters: int, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        self.ds = ds if ds is not None else generate_dataset(self.model, n_data, seed=seed)
        space = self.model.space
        n_in = self.model.net_space.n_dims + 2 + space.onehot_width
        rng = jax.random.PRNGKey(seed)
        self.params = L.mlp_init(rng, n_in, [self.neurons] * self.hidden_layers,
                                 self._n_actions)
        optim = adam(self.lr)
        opt = optim.init(self.params)

        def pg_loss(params, states, actions, advantages):
            logits = self._logits(params, *states)
            logp = jax.nn.log_softmax(logits, axis=-1)
            act_logp = jnp.take_along_axis(logp, actions[:, None], axis=-1)[:, 0]
            return -jnp.mean(act_logp * advantages)

        @jax.jit
        def update(params, opt, states, actions, advantages):
            loss, grads = jax.value_and_grad(pg_loss)(params, states, actions, advantages)
            upd, opt = optim.update(grads, opt)
            return apply_updates(params, upd), opt, loss

        np_rng = np.random.default_rng(seed)
        baseline = 0.0
        for it in range(iters):
            # sample a batch of tasks from the dataset rows
            rows = np_rng.integers(0, self.ds.n, self.batch_tasks)
            b = encode_batch(self.model, self.ds, rows)
            net_idx = b["net_idx"]
            lo, po = b["lat_obj"], b["pow_obj"]
            cfg = space.sample_indices(np_rng, self.batch_tasks)
            lat, pw = self.model.evaluate_indices(net_idx, cfg)
            viol = _violation(lat, pw, lo, po)

            traj_states, traj_actions, traj_rewards = [], [], []
            for t in range(self.rollout_len):
                cfg_oh = space.onehot_from_indices(cfg)
                states = (jnp.asarray(b["net_enc"]), jnp.asarray(b["obj_enc"]),
                          jnp.asarray(cfg_oh))
                logits = np.asarray(self._logits(self.params, *states))
                # sample actions
                z = np_rng.gumbel(size=logits.shape)
                actions = np.argmax(logits + z, axis=-1).astype(np.int64)
                new_cfg = self._apply_actions(cfg, actions)
                lat, pw = self.model.evaluate_indices(net_idx, new_cfg)
                new_viol = _violation(lat, pw, lo, po)
                reward = (viol - new_viol) + self.sat_bonus * (new_viol == 0.0)
                traj_states.append(states)
                traj_actions.append(actions)
                traj_rewards.append(reward)
                cfg, viol = new_cfg, new_viol

            # discounted returns
            ret = np.zeros_like(traj_rewards[0])
            all_s, all_a, all_adv = [], [], []
            for t in reversed(range(self.rollout_len)):
                ret = traj_rewards[t] + self.gamma * ret
                all_s.append(traj_states[t])
                all_a.append(traj_actions[t])
                all_adv.append(ret.copy())
            adv = np.concatenate(all_adv)
            baseline = 0.9 * baseline + 0.1 * float(adv.mean())
            adv = (adv - baseline) / (adv.std() + 1e-6)
            states = tuple(jnp.concatenate([s[i] for s in all_s]) for i in range(3))
            actions = jnp.asarray(np.concatenate(all_a))
            self.params, opt, loss = update(self.params, opt, states, actions,
                                            jnp.asarray(adv, jnp.float32))
            if log_every and it % log_every == 0:
                print(f"[drl] iter={it} loss={float(loss):.4f} "
                      f"final_viol={viol.mean():.4f} sat={(viol == 0).mean():.3f}")
        return self

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        t0 = time.time()
        space = self.model.space
        rng = np.random.default_rng(seed)
        lo, po = float(lat_obj), float(pow_obj)
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded([lo], [po])
        cfg = space.sample_indices(rng, 1)
        lat, pw = self.model.evaluate_indices(net_idx[None], cfg)
        best = (cfg[0].copy(), float(lat[0]), float(pw[0]),
                float(_violation(lat, pw, lo, po)[0]))
        n_eval = 1
        for t in range(self.rollout_len):
            cfg_oh = space.onehot_from_indices(cfg)
            logits = np.asarray(self._logits(self.params, jnp.asarray(net_enc),
                                             jnp.asarray(obj_enc), jnp.asarray(cfg_oh)))
            actions = np.argmax(logits, axis=-1)  # greedy at DSE time
            if t > 0 and rng.random() < 0.3:      # light exploration
                actions = np.array([rng.integers(0, self._n_actions)])
            cfg = self._apply_actions(cfg, actions)
            lat, pw = self.model.evaluate_indices(net_idx[None], cfg)
            n_eval += 1
            v = float(_violation(lat, pw, lo, po)[0])
            l_, p_ = float(lat[0]), float(pw[0])
            if v < best[3] or (v == best[3] and np.isfinite(l_) and l_ + p_ < best[1] + best[2]):
                best = (cfg[0].copy(), l_, p_, v)
        c, bl, bp, bv = best
        satisfied = np.isfinite(bl) and bl <= lo * 1.01 and bp <= po * 1.01
        sel = Selection(cfg_idx=c, latency=bl, power=bp, satisfied=bool(satisfied),
                        n_candidates=n_eval)
        return DSEResult(sel, lo, po, time.time() - t0)

    def explore_tasks(self, tasks: DSETask, seed: int = 0):
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                             seed=seed + i)
                for i in range(tasks.net_idx.shape[0])]
