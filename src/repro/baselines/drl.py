"""Deep-reinforcement-learning DSE baseline (paper §7.1.4, ConfuciuX-style).

Policy-gradient (REINFORCE with a moving baseline).  The state is the
current (network parameters, objectives, configuration); actions set one
configuration dimension to one of its choices; the reward is the decrease
in objective violation, with a bonus when the state satisfies the
objectives.  An MLP actor is trained offline over dataset-derived tasks;
at DSE time a short greedy rollout is run and the best visited
configuration is returned (iterative DSE, but with a learned policy).

Violations are clipped to ``VIOL_CLIP`` per metric: infeasible configs used
to map to ~1e9 violations whose one-step rewards swamped the moving
baseline and the advantage normalization.

DSE-time rollouts have two routes:

- **device** (default when the model has a jnp oracle): the whole rollout
  (policy forward -> action -> ``DesignModel.evaluate_jax`` scoring) is one
  jitted ``lax.scan`` vmapped over the task batch — ONE dispatch chain for
  T tasks instead of (rollout_len x T) host oracle calls.  Lane t draws
  from PRNGKey(seed + t), so a batched lane is bitwise-equal to the
  single-task device run with seed + t; winners are re-scored once by the
  float64 host oracle (the ``select_batch`` rule).
- **host** (fallback for models without a jnp oracle): the original numpy
  loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard
from repro.core.explorer import task_keys
from repro.core.selector import Selection, is_satisfied
from repro.core.dse_api import DSEResult, row_seeds
from repro.core.train import encode_batch
from repro.dataset.generator import Dataset, DSETask, generate_dataset
from repro.design_models.base import DesignModel
from repro.nn import layers as L
from repro.optim import adam, apply_updates

#: per-metric violation cap: bounds any one-step reward to
#: 2 * VIOL_CLIP + sat_bonus regardless of how infeasible a config is
VIOL_CLIP = 10.0


def _violation(lat, pw, lo, po):
    """Relative objective violation, each metric's term clipped to
    VIOL_CLIP (NaN/inf metrics saturate at the clip, not at ~1e9)."""
    lat = np.where(np.isnan(lat), np.inf, np.asarray(lat, np.float64))
    pw = np.where(np.isnan(pw), np.inf, np.asarray(pw, np.float64))
    lv = np.minimum(np.maximum(0.0, (lat - lo) / lo), VIOL_CLIP)
    pv = np.minimum(np.maximum(0.0, (pw - po) / po), VIOL_CLIP)
    return lv + pv


def _drl_rollout_kernel(model: DesignModel, rollout_len: int,
                        explore_eps: float):
    """Jitted vmapped DSE rollout: (params, net_idx (T,), net_enc, obj_enc,
    lo (T,), po (T,), keys (T,2)) -> (best cfg (T, n_dims), n_eval)."""
    space = model.space
    n_dims = space.n_dims
    sizes = jnp.asarray(space.group_sizes, jnp.int32)
    offs = np.concatenate([[0], np.cumsum(space.group_sizes)])
    starts = jnp.asarray(offs[:-1], jnp.int32)
    ends = jnp.asarray(offs[1:], jnp.int32)
    n_actions = space.onehot_width

    def onehot(cfg):
        return jnp.concatenate(
            [jax.nn.one_hot(cfg[i], d.n) for i, d in enumerate(space.dims)])

    def apply_action(cfg, a):
        di = jnp.searchsorted(ends, a, side="right")   # a's group
        return cfg.at[di].set(a - starts[di])

    def score(net_idx, cfg, lo, po):
        lat, pw = model.evaluate_jax_indices(net_idx[None, :], cfg[None, :])
        lat = jnp.where(jnp.isnan(lat[0]), jnp.inf, lat[0]).astype(jnp.float32)
        pw = jnp.where(jnp.isnan(pw[0]), jnp.inf, pw[0]).astype(jnp.float32)
        lv = jnp.minimum(jnp.maximum(0.0, (lat - lo) / lo), VIOL_CLIP)
        pv = jnp.minimum(jnp.maximum(0.0, (pw - po) / po), VIOL_CLIP)
        return lat, pw, lv + pv

    def one_task(params, net_idx, net_enc, obj_enc, lo, po, key):
        key, k0 = jax.random.split(key)
        cfg = jnp.floor(
            jax.random.uniform(k0, (n_dims,)) * sizes).astype(jnp.int32)
        lat0, pw0, v0 = score(net_idx, cfg, lo, po)

        def step(carry, t):
            key, cfg, best, best_l, best_p, best_v = carry
            x = jnp.concatenate([net_enc, obj_enc, onehot(cfg)])
            logits = L.mlp_apply(params, x[None])[0]
            key, ke, ka = jax.random.split(key, 3)
            a = jnp.where(
                (t > 0) & (jax.random.uniform(ke) < explore_eps),
                jax.random.randint(ka, (), 0, n_actions),
                jnp.argmax(logits).astype(jnp.int32))   # greedy at DSE time
            cfg = apply_action(cfg, a.astype(jnp.int32))
            lat, pw, v = score(net_idx, cfg, lo, po)
            improved = (v < best_v) | (
                (v == best_v) & jnp.isfinite(lat)
                & (lat + pw < best_l + best_p))
            best = jnp.where(improved, cfg, best)
            best_l = jnp.where(improved, lat, best_l)
            best_p = jnp.where(improved, pw, best_p)
            best_v = jnp.where(improved, v, best_v)
            return (key, cfg, best, best_l, best_p, best_v), None

        carry = (key, cfg, cfg, lat0, pw0, v0)
        (_, _, best, _, _, _), _ = jax.lax.scan(
            step, carry, jnp.arange(rollout_len))
        return best

    return jax.jit(jax.vmap(one_task,
                            in_axes=(None, 0, 0, 0, 0, 0, 0)))


@dataclasses.dataclass
class PolicyGradientDRL:
    model: DesignModel
    hidden_layers: int = 3
    neurons: int = 256
    lr: float = 1e-4
    rollout_len: int = 16
    batch_tasks: int = 64
    gamma: float = 0.95
    sat_bonus: float = 2.0
    explore_eps: float = 0.3
    seed: int = 0

    method_name = "DRL"

    def __post_init__(self):
        self.ds: Optional[Dataset] = None
        self.params = None
        space = self.model.space
        self._n_actions = space.onehot_width  # action = (dim, choice) flattened

        @jax.jit
        def policy_logits(params, net_enc, obj_enc, cfg_onehot):
            x = jnp.concatenate([net_enc, obj_enc, cfg_onehot], axis=-1)
            return L.mlp_apply(params, x)

        self._logits = policy_logits

    # --- helpers -------------------------------------------------------------
    def _apply_actions(self, cfg_idx: np.ndarray, actions: np.ndarray) -> np.ndarray:
        """actions: flat indices into onehot_width -> set (dim, choice)."""
        space = self.model.space
        out = cfg_idx.copy()
        off = 0
        for di, d in enumerate(space.dims):
            in_group = (actions >= off) & (actions < off + d.n)
            out[in_group, di] = actions[in_group] - off
            off += d.n
        return out

    def _rollout_kernel(self):
        key = (self.rollout_len, self.explore_eps)
        kernels = self.model.__dict__.setdefault("_drl_kernels", {})
        if key not in kernels:
            kernels[key] = _drl_rollout_kernel(self.model, self.rollout_len,
                                               self.explore_eps)
        return kernels[key]

    def attach(self, ds: Dataset, params) -> "PolicyGradientDRL":
        """Serving entry (mirrors GANDSE.attach): wire a dataset (for its
        normalizers) and trained policy params without retraining."""
        self.ds = ds
        self.params = params
        return self

    def init_params(self, seed: int = 0):
        """Fresh policy params — the single definition of the input width
        (net params + 2 objective channels + config one-hot), shared by
        `train` and the bench/serving `attach` path."""
        n_in = self.model.net_space.n_dims + 2 + self.model.space.onehot_width
        return L.mlp_init(jax.random.PRNGKey(seed), n_in,
                          [self.neurons] * self.hidden_layers,
                          self._n_actions)

    def train(self, n_data: int, iters: int, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        self.ds = ds if ds is not None else generate_dataset(self.model, n_data, seed=seed)
        space = self.model.space
        self.params = self.init_params(seed)
        optim = adam(self.lr)
        opt = optim.init(self.params)

        def pg_loss(params, states, actions, advantages):
            logits = self._logits(params, *states)
            logp = jax.nn.log_softmax(logits, axis=-1)
            act_logp = jnp.take_along_axis(logp, actions[:, None], axis=-1)[:, 0]
            return -jnp.mean(act_logp * advantages)

        @jax.jit
        def update(params, opt, states, actions, advantages):
            loss, grads = jax.value_and_grad(pg_loss)(params, states, actions, advantages)
            upd, opt = optim.update(grads, opt)
            return apply_updates(params, upd), opt, loss

        np_rng = np.random.default_rng(seed)
        baseline = 0.0
        for it in range(iters):
            # sample a batch of tasks from the dataset rows
            rows = np_rng.integers(0, self.ds.n, self.batch_tasks)
            b = encode_batch(self.model, self.ds, rows)
            net_idx = b["net_idx"]
            lo, po = b["lat_obj"], b["pow_obj"]
            cfg = space.sample_indices(np_rng, self.batch_tasks)
            lat, pw = self.model.evaluate_indices(net_idx, cfg)
            viol = _violation(lat, pw, lo, po)

            traj_states, traj_actions, traj_rewards = [], [], []
            for t in range(self.rollout_len):
                cfg_oh = space.onehot_from_indices(cfg)
                states = (jnp.asarray(b["net_enc"]), jnp.asarray(b["obj_enc"]),
                          jnp.asarray(cfg_oh))
                logits = np.asarray(self._logits(self.params, *states))
                # sample actions
                z = np_rng.gumbel(size=logits.shape)
                actions = np.argmax(logits + z, axis=-1).astype(np.int64)
                new_cfg = self._apply_actions(cfg, actions)
                lat, pw = self.model.evaluate_indices(net_idx, new_cfg)
                new_viol = _violation(lat, pw, lo, po)
                reward = (viol - new_viol) + self.sat_bonus * (new_viol == 0.0)
                traj_states.append(states)
                traj_actions.append(actions)
                traj_rewards.append(reward)
                cfg, viol = new_cfg, new_viol

            # discounted returns
            ret = np.zeros_like(traj_rewards[0])
            all_s, all_a, all_adv = [], [], []
            for t in reversed(range(self.rollout_len)):
                ret = traj_rewards[t] + self.gamma * ret
                all_s.append(traj_states[t])
                all_a.append(traj_actions[t])
                all_adv.append(ret.copy())
            adv = np.concatenate(all_adv)
            baseline = 0.9 * baseline + 0.1 * float(adv.mean())
            adv = (adv - baseline) / (adv.std() + 1e-6)
            states = tuple(jnp.concatenate([s[i] for s in all_s]) for i in range(3))
            actions = jnp.asarray(np.concatenate(all_a))
            self.params, opt, loss = update(self.params, opt, states, actions,
                                            jnp.asarray(adv, jnp.float32))
            if log_every and it % log_every == 0:
                print(f"[drl] iter={it} loss={float(loss):.4f} "
                      f"final_viol={viol.mean():.4f} sat={(viol == 0).mean():.3f}")
        return self

    # --- device route -------------------------------------------------------
    def _explore_device(self, tasks: DSETask, seed: int) -> List[DSEResult]:
        n_tasks = int(tasks.net_idx.shape[0])
        t0 = time.time()
        # rollout lanes shard over the active task mesh (pad, run, discard
        # padded lanes) — the policy params stay replicated (in_axes=None)
        seeds = row_seeds(seed, n_tasks)
        tasks_p, seeds, n_tasks = shard.pad_tasks(tasks, seeds)
        n_pad = int(tasks_p.net_idx.shape[0])
        net_enc = self.ds.net_encoded(self.model, tasks_p.net_idx)
        obj_enc = self.ds.obj_encoded(tasks_p.lat_obj, tasks_p.pow_obj)
        best = np.asarray(self._rollout_kernel()(
            shard.replicate(self.params),
            shard.put_sharded(np.asarray(tasks_p.net_idx, np.int32)),
            shard.put_sharded(net_enc), shard.put_sharded(obj_enc),
            shard.put_sharded(np.asarray(tasks_p.lat_obj, np.float32)),
            shard.put_sharded(np.asarray(tasks_p.pow_obj, np.float32)),
            shard.put_sharded(task_keys(seeds, n_pad))))[:n_tasks]
        # one float64 host-oracle call re-scores every winner
        lat64, pw64 = self.model.evaluate_indices(tasks.net_idx, best)
        per_task = (time.time() - t0) / n_tasks
        out = []
        for t in range(n_tasks):
            lo, po = float(tasks.lat_obj[t]), float(tasks.pow_obj[t])
            bl, bp = float(lat64[t]), float(pw64[t])
            sel = Selection(cfg_idx=best[t].copy(), latency=bl, power=bp,
                            satisfied=is_satisfied(bl, bp, lo, po),
                            n_candidates=self.rollout_len + 1)
            out.append(DSEResult(sel, lo, po, per_task))
        return out

    # --- host route ---------------------------------------------------------
    def _explore_host(self, net_idx: np.ndarray, lat_obj: float,
                      pow_obj: float, seed: int) -> DSEResult:
        t0 = time.time()
        space = self.model.space
        rng = np.random.default_rng(seed)
        lo, po = float(lat_obj), float(pow_obj)
        net_enc = self.ds.net_encoded(self.model, np.atleast_2d(net_idx))
        obj_enc = self.ds.obj_encoded([lo], [po])
        cfg = space.sample_indices(rng, 1)
        lat, pw = self.model.evaluate_indices(net_idx[None], cfg)
        best = (cfg[0].copy(), float(lat[0]), float(pw[0]),
                float(_violation(lat, pw, lo, po)[0]))
        n_eval = 1
        for t in range(self.rollout_len):
            cfg_oh = space.onehot_from_indices(cfg)
            logits = np.asarray(self._logits(self.params, jnp.asarray(net_enc),
                                             jnp.asarray(obj_enc), jnp.asarray(cfg_oh)))
            actions = np.argmax(logits, axis=-1)  # greedy at DSE time
            if t > 0 and rng.random() < self.explore_eps:  # light exploration
                actions = np.array([rng.integers(0, self._n_actions)])
            cfg = self._apply_actions(cfg, actions)
            lat, pw = self.model.evaluate_indices(net_idx[None], cfg)
            n_eval += 1
            v = float(_violation(lat, pw, lo, po)[0])
            l_, p_ = float(lat[0]), float(pw[0])
            if v < best[3] or (v == best[3] and np.isfinite(l_) and l_ + p_ < best[1] + best[2]):
                best = (cfg[0].copy(), l_, p_, v)
        c, bl, bp, bv = best
        sel = Selection(cfg_idx=c, latency=bl, power=bp,
                        satisfied=is_satisfied(bl, bp, lo, po),
                        n_candidates=n_eval)
        return DSEResult(sel, lo, po, time.time() - t0)

    # --- public API ---------------------------------------------------------
    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0, use_jax: Optional[bool] = None) -> DSEResult:
        # a model without a jnp oracle always takes the host route, even
        # when the device route is requested (the GANDSE fallback rule)
        use_jax = self.model.has_jax_oracle and (use_jax is None or use_jax)
        if use_jax:
            tasks = DSETask.single(net_idx, lat_obj, pow_obj)
            return self._explore_device(tasks, seed)[0]
        return self._explore_host(net_idx, lat_obj, pow_obj, seed)

    def explore_tasks(self, tasks: DSETask, seed: int = 0,
                      batched: Optional[bool] = None) -> List[DSEResult]:
        batched = self.model.has_jax_oracle and (batched is None or batched)
        n_tasks = int(tasks.net_idx.shape[0])
        if n_tasks == 0:
            return []
        if batched:
            return self._explore_device(tasks, seed)
        seeds = row_seeds(seed, n_tasks)
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i],
                             tasks.pow_obj[i], seed=int(seeds[i]),
                             use_jax=False)
                for i in range(n_tasks)]
