from repro.baselines.sa import SimulatedAnnealing  # noqa: F401
from repro.baselines.mlp import LargeMLP  # noqa: F401
from repro.baselines.drl import PolicyGradientDRL  # noqa: F401
from repro.baselines.random_search import RandomSearch  # noqa: F401
