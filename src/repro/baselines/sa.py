"""Simulated annealing DSE baseline (paper §7.1.4).

Iterative DSE in the classic Fig. 1 loop: the configuration-updating
algorithm is SA over the discrete choice indices; the design model scores
each visited configuration.  "SA terminates once the user's objectives are
satisfied, or the temperature is 3e-8 x the initial one."
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.selector import Selection
from repro.core.dse_api import DSEResult
from repro.dataset.generator import DSETask
from repro.design_models.base import DesignModel


def _violation(lat, pw, lo, po):
    return max(0.0, (lat - lo) / lo) + max(0.0, (pw - po) / po)


@dataclasses.dataclass
class SimulatedAnnealing:
    model: DesignModel
    t_init: float = 1.0
    t_stop_frac: float = 3e-8
    cooling: float = 0.95
    steps_per_temp: int = 4
    seed: int = 0

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: Optional[int] = None) -> DSEResult:
        rng = np.random.default_rng(self.seed if seed is None else seed)
        space = self.model.space
        t0 = time.time()
        lo, po = float(lat_obj), float(pow_obj)

        cur = space.sample_indices(rng, 1)[0]
        lat, pw = self.model.evaluate_indices(net_idx[None], cur[None])
        cur_l, cur_p = float(lat[0]), float(pw[0])
        cur_e = _violation(cur_l, cur_p, lo, po) if np.isfinite(cur_l) else 1e9
        best = (cur.copy(), cur_l, cur_p, cur_e)
        n_eval = 1

        temp = self.t_init
        while temp > self.t_init * self.t_stop_frac and best[3] > 0.0:
            for _ in range(self.steps_per_temp):
                nxt = cur.copy()
                d = rng.integers(0, space.n_dims)
                if rng.random() < 0.5:  # local move
                    nxt[d] = int(np.clip(nxt[d] + rng.choice([-1, 1]), 0,
                                         space.dims[d].n - 1))
                else:                   # random re-draw
                    nxt[d] = rng.integers(0, space.dims[d].n)
                lat, pw = self.model.evaluate_indices(net_idx[None], nxt[None])
                n_eval += 1
                nl, np_ = float(lat[0]), float(pw[0])
                e = _violation(nl, np_, lo, po) if np.isfinite(nl) else 1e9
                if e < cur_e or rng.random() < np.exp(-(e - cur_e) / max(temp, 1e-12)):
                    cur, cur_l, cur_p, cur_e = nxt, nl, np_, e
                    if e < best[3] or (e == best[3] and nl + np_ < best[1] + best[2]):
                        best = (cur.copy(), cur_l, cur_p, e)
                if best[3] == 0.0:
                    break
            temp *= self.cooling

        cfg, bl, bp, be = best
        satisfied = bl <= lo * 1.01 and bp <= po * 1.01
        sel = Selection(cfg_idx=cfg, latency=bl, power=bp,
                        satisfied=bool(satisfied), n_candidates=n_eval)
        return DSEResult(sel, lo, po, time.time() - t0)

    def explore_tasks(self, tasks: DSETask, seed: int = 0):
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                             seed=seed + i)
                for i in range(tasks.net_idx.shape[0])]
