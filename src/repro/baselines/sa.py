"""Simulated annealing DSE baseline (paper §7.1.4).

Iterative DSE in the classic Fig. 1 loop: the configuration-updating
algorithm is SA over the discrete choice indices; the design model scores
each visited configuration.  "SA terminates once the user's objectives are
satisfied, or the temperature is 3e-8 x the initial one."

Two routes share the annealing schedule:

- **device** (default when the model has a jnp oracle): the whole anneal is
  one jitted ``lax.while_loop`` — propose / score via
  ``DesignModel.evaluate_jax`` / accept — vmapped over the task batch, so a
  batch costs ONE dispatch instead of one host oracle call per visited
  config.  Tasks whose best violation hits zero freeze (the batched while
  keeps them fixed), matching the sequential early exit, so lane t is
  bitwise-equal to a single-task device run with seed + t.
- **host** (fallback for models without a jnp oracle, or ``use_jax=False``):
  the original numpy loop with one ``evaluate_indices`` call per step.

Winners from the device route are re-scored once with the float64 host
oracle so reported metrics stay precision-consistent with the host route
(the same rule as ``select_batch``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import shard
from repro.core.explorer import task_keys
from repro.core.selector import Selection, is_satisfied
from repro.core.dse_api import DSEResult, row_seeds
from repro.dataset.generator import Dataset, DSETask
from repro.design_models.base import DesignModel

#: violation assigned to infeasible (non-finite metric) configurations
_BIG = 1e9


def _violation(lat, pw, lo, po):
    """Objective violation; non-finite (inf/NaN) metrics -> _BIG.

    Both metrics must be guarded: a finite-latency/non-finite-power config
    otherwise yields inf/NaN energies whose comparisons silently corrupt
    the accept/best logic (a NaN power even counts as zero violation, i.e.
    "satisfied").
    """
    if not (np.isfinite(lat) and np.isfinite(pw)):
        return _BIG
    return max(0.0, (lat - lo) / lo) + max(0.0, (pw - po) / po)


def _sa_device_kernel(model: DesignModel, t_init: float, cooling: float,
                      steps_per_temp: int, max_steps: int):
    """Jitted vmapped anneal: (net_idx (T,), lo (T,), po (T,), keys (T,2))
    -> (best cfg (T, n_dims), best violation (T,), n_eval (T,))."""
    space = model.space
    n_dims = space.n_dims
    sizes = jnp.asarray(space.group_sizes, jnp.int32)

    def viol(lat, pw, lo, po):
        lat = lat.astype(jnp.float32)
        pw = pw.astype(jnp.float32)
        v = (jnp.maximum(0.0, (lat - lo) / lo)
             + jnp.maximum(0.0, (pw - po) / po))
        return jnp.where(jnp.isfinite(lat) & jnp.isfinite(pw), v,
                         jnp.float32(_BIG))

    def score(net_idx, cfg, lo, po):
        lat, pw = model.evaluate_jax_indices(net_idx[None, :], cfg[None, :])
        return lat[0], pw[0], viol(lat[0], pw[0], lo, po)

    def one_task(net_idx, lo, po, key):
        key, k0 = jax.random.split(key)
        cur = jnp.floor(
            jax.random.uniform(k0, (n_dims,)) * sizes).astype(jnp.int32)
        lat0, pw0, e0 = score(net_idx, cur, lo, po)

        def cond(c):
            (_, _, _, _, _, _, best_e, _, step) = c
            return (step < max_steps) & (best_e > 0.0)

        def body(c):
            key, cur, cur_e, best, best_l, best_p, best_e, n_eval, step = c
            temp = t_init * jnp.power(
                jnp.float32(cooling), (step // steps_per_temp).astype(jnp.float32))
            key, kd, km, ks, kr, ka = jax.random.split(key, 6)
            d = jax.random.randint(kd, (), 0, n_dims)
            nd = sizes[d]
            local = jnp.where(
                jax.random.uniform(ks) < 0.5, -1, 1) + cur[d]   # +-1 move
            local = jnp.clip(local, 0, nd - 1)
            redraw = jnp.floor(jax.random.uniform(kr) * nd).astype(jnp.int32)
            nxt = cur.at[d].set(
                jnp.where(jax.random.uniform(km) < 0.5, local, redraw))
            lat, pw, e = score(net_idx, nxt, lo, po)
            accept = (e < cur_e) | (
                jax.random.uniform(ka)
                < jnp.exp(-(e - cur_e) / jnp.maximum(temp, 1e-12)))
            cur = jnp.where(accept, nxt, cur)
            cur_e = jnp.where(accept, e, cur_e)
            improved = accept & (
                (e < best_e)
                | ((e == best_e) & (lat + pw < best_l + best_p)))
            best = jnp.where(improved, nxt, best)
            best_l = jnp.where(improved, lat, best_l)
            best_p = jnp.where(improved, pw, best_p)
            best_e = jnp.where(improved, e, best_e)
            return (key, cur, cur_e, best, best_l, best_p, best_e,
                    n_eval + 1, step + 1)

        carry = (key, cur, e0, cur, lat0.astype(jnp.float32),
                 pw0.astype(jnp.float32), e0, jnp.int32(1), jnp.int32(0))
        (_, _, _, best, _, _, best_e, n_eval, _) = jax.lax.while_loop(
            cond, body, carry)
        return best, best_e, n_eval

    return jax.jit(jax.vmap(one_task))


@dataclasses.dataclass
class SimulatedAnnealing:
    model: DesignModel
    t_init: float = 1.0
    t_stop_frac: float = 3e-8
    cooling: float = 0.95
    steps_per_temp: int = 4
    seed: int = 0

    method_name = "SA"

    def train(self, n_data: int = 0, iters: int = 0, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        """SA is model-free — training is a no-op (DSEMethod protocol)."""
        return self

    @property
    def max_steps(self) -> int:
        """Proposal budget of one anneal: temperatures until the stop
        fraction, times steps per temperature (same count as the host
        while loop)."""
        n_temps = int(np.ceil(np.log(self.t_stop_frac) / np.log(self.cooling)))
        return n_temps * self.steps_per_temp

    def _kernel(self):
        key = (self.t_init, self.cooling, self.steps_per_temp, self.max_steps)
        kernels = self.model.__dict__.setdefault("_sa_kernels", {})
        if key not in kernels:
            kernels[key] = _sa_device_kernel(self.model, self.t_init,
                                             self.cooling,
                                             self.steps_per_temp,
                                             self.max_steps)
        return kernels[key]

    # --- device route -------------------------------------------------------
    def _explore_device(self, tasks: DSETask, seed: int) -> List[DSEResult]:
        n_tasks = int(tasks.net_idx.shape[0])
        t0 = time.time()
        # under an active task mesh the anneal lanes shard over the mesh's
        # batch axes (pad to the shard multiple, discard padded lanes) —
        # same jitted while_loop, same per-lane streams, same Selections
        seeds = row_seeds(seed, n_tasks)
        tasks_p, seeds, n_tasks = shard.pad_tasks(tasks, seeds)
        n_pad = int(tasks_p.net_idx.shape[0])
        best, best_e, n_eval = self._kernel()(
            shard.put_sharded(np.asarray(tasks_p.net_idx, np.int32)),
            shard.put_sharded(np.asarray(tasks_p.lat_obj, np.float32)),
            shard.put_sharded(np.asarray(tasks_p.pow_obj, np.float32)),
            shard.put_sharded(task_keys(seeds, n_pad)))
        best = np.asarray(best)[:n_tasks]
        n_eval = np.asarray(n_eval)[:n_tasks]
        # one float64 host-oracle call re-scores every winner (metrics and
        # `satisfied` stay precision-consistent with the host route)
        lat64, pw64 = self.model.evaluate_indices(tasks.net_idx, best)
        per_task = (time.time() - t0) / n_tasks
        out = []
        for t in range(n_tasks):
            lo, po = float(tasks.lat_obj[t]), float(tasks.pow_obj[t])
            bl, bp = float(lat64[t]), float(pw64[t])
            sel = Selection(cfg_idx=best[t].copy(), latency=bl, power=bp,
                            satisfied=is_satisfied(bl, bp, lo, po),
                            n_candidates=int(n_eval[t]))
            out.append(DSEResult(sel, lo, po, per_task))
        return out

    # --- host route ---------------------------------------------------------
    def _explore_host(self, net_idx: np.ndarray, lat_obj: float,
                      pow_obj: float, seed: Optional[int]) -> DSEResult:
        rng = np.random.default_rng(self.seed if seed is None else seed)
        space = self.model.space
        t0 = time.time()
        lo, po = float(lat_obj), float(pow_obj)

        cur = space.sample_indices(rng, 1)[0]
        lat, pw = self.model.evaluate_indices(net_idx[None], cur[None])
        cur_l, cur_p = float(lat[0]), float(pw[0])
        cur_e = _violation(cur_l, cur_p, lo, po)
        best = (cur.copy(), cur_l, cur_p, cur_e)
        n_eval = 1

        temp = self.t_init
        while temp > self.t_init * self.t_stop_frac and best[3] > 0.0:
            for _ in range(self.steps_per_temp):
                nxt = cur.copy()
                d = rng.integers(0, space.n_dims)
                if rng.random() < 0.5:  # local move
                    nxt[d] = int(np.clip(nxt[d] + rng.choice([-1, 1]), 0,
                                         space.dims[d].n - 1))
                else:                   # random re-draw
                    nxt[d] = rng.integers(0, space.dims[d].n)
                lat, pw = self.model.evaluate_indices(net_idx[None], nxt[None])
                n_eval += 1
                nl, np_ = float(lat[0]), float(pw[0])
                e = _violation(nl, np_, lo, po)
                if e < cur_e or rng.random() < np.exp(-(e - cur_e) / max(temp, 1e-12)):
                    cur, cur_l, cur_p, cur_e = nxt, nl, np_, e
                    if e < best[3] or (e == best[3] and nl + np_ < best[1] + best[2]):
                        best = (cur.copy(), nl, np_, e)
                if best[3] == 0.0:
                    break
            temp *= self.cooling

        cfg, bl, bp, be = best
        sel = Selection(cfg_idx=cfg, latency=bl, power=bp,
                        satisfied=is_satisfied(bl, bp, lo, po),
                        n_candidates=n_eval)
        return DSEResult(sel, lo, po, time.time() - t0)

    # --- public API ---------------------------------------------------------
    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: Optional[int] = None,
                use_jax: Optional[bool] = None) -> DSEResult:
        # a model without a jnp oracle always takes the host route, even
        # when the device route is requested (the GANDSE fallback rule)
        use_jax = self.model.has_jax_oracle and (use_jax is None or use_jax)
        if use_jax:
            tasks = DSETask.single(net_idx, lat_obj, pow_obj)
            return self._explore_device(
                tasks, self.seed if seed is None else seed)[0]
        return self._explore_host(net_idx, lat_obj, pow_obj, seed)

    def explore_tasks(self, tasks: DSETask, seed: int = 0,
                      batched: Optional[bool] = None) -> List[DSEResult]:
        batched = self.model.has_jax_oracle and (batched is None or batched)
        n_tasks = int(tasks.net_idx.shape[0])
        if n_tasks == 0:
            return []
        if batched:
            return self._explore_device(tasks, seed)
        seeds = row_seeds(seed, n_tasks)
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i],
                             tasks.pow_obj[i], seed=int(seeds[i]),
                             use_jax=False)
                for i in range(n_tasks)]
