"""Random-search DSE baseline (sanity floor, not in the paper's table).

Uniformly samples N configurations and applies the Algorithm 2 selector.
Useful as the weakest-reasonable baseline and in property tests (any
learned method should beat it at equal evaluation budget).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.selector import select
from repro.core.dse_api import DSEResult
from repro.dataset.generator import DSETask
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class RandomSearch:
    model: DesignModel
    n_samples: int = 256

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        t0 = time.time()
        rng = np.random.default_rng(seed)
        cands = self.model.space.sample_indices(rng, self.n_samples)
        sel = select(self.model, net_idx, cands, lat_obj, pow_obj)
        return DSEResult(sel, float(lat_obj), float(pow_obj), time.time() - t0)

    def explore_tasks(self, tasks: DSETask, seed: int = 0):
        return [self.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                             seed=seed + i)
                for i in range(tasks.net_idx.shape[0])]
