"""Random-search DSE baseline (sanity floor, not in the paper's table).

Uniformly samples N configurations and applies the Algorithm 2 selector.
Useful as the weakest-reasonable baseline and in property tests (any
learned method should beat it at equal evaluation budget).

``explore_tasks`` serves a task batch device-resident: candidate sampling
stays on host (cheap, and bitwise-identical to the per-task route), the T
Algorithm 2 update chains run as one vmapped scan (``select_batch``).
Models without a jnp oracle fall back to the sequential host loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core.selector import select, select_batch
from repro.core.dse_api import DSEResult, row_seeds
from repro.dataset.generator import Dataset, DSETask
from repro.design_models.base import DesignModel


@dataclasses.dataclass
class RandomSearch:
    model: DesignModel
    n_samples: int = 256

    method_name = "RandomSearch"

    def train(self, n_data: int = 0, iters: int = 0, seed: int = 0,
              ds: Optional[Dataset] = None, log_every: int = 0):
        """Random search is model-free — training is a no-op (DSEMethod
        protocol)."""
        return self

    def _candidates(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return self.model.space.sample_indices(rng, self.n_samples)

    def explore(self, net_idx: np.ndarray, lat_obj: float, pow_obj: float,
                seed: int = 0) -> DSEResult:
        t0 = time.time()
        cands = self._candidates(seed)
        sel = select(self.model, net_idx, cands, lat_obj, pow_obj)
        return DSEResult(sel, float(lat_obj), float(pow_obj), time.time() - t0)

    def explore_tasks(self, tasks: DSETask, seed: int = 0,
                      batched: Optional[bool] = None) -> List[DSEResult]:
        # models without a jnp oracle always take the host route (the
        # GANDSE fallback rule), even when the batched route is requested
        batched = self.model.has_jax_oracle and (batched is None or batched)
        n_tasks = int(tasks.net_idx.shape[0])
        if n_tasks == 0:
            return []
        seeds = row_seeds(seed, n_tasks)
        if not batched:
            return [self.explore(tasks.net_idx[i], tasks.lat_obj[i],
                                 tasks.pow_obj[i], seed=int(seeds[i]))
                    for i in range(n_tasks)]
        t0 = time.time()
        # task t samples from default_rng(seeds[t]): same candidate sets as
        # the sequential route, whatever the batch composition
        cand = np.stack([self._candidates(int(seeds[t]))
                         for t in range(n_tasks)])
        valid = np.ones(cand.shape[:2], bool)
        counts = np.full(n_tasks, self.n_samples)
        sels = select_batch(self.model, tasks.net_idx, cand, valid, counts,
                            tasks.lat_obj, tasks.pow_obj)
        per_task = (time.time() - t0) / n_tasks
        return [
            DSEResult(sel, float(tasks.lat_obj[i]), float(tasks.pow_obj[i]),
                      per_task)
            for i, sel in enumerate(sels)
        ]
