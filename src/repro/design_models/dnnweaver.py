"""The DnnWeaver design model (paper §7.1.1).

Systolic-array template in the style of the open-source DnnWeaver v2 code.
Low-dimension design space (Table 1: configurations without '*'): PE number
and the three SRAM sizes.  The mapping (tiling) is derived internally by
the template's own greedy schedule — the user does not control it — and the
DRAM bandwidths are fixed board properties.  The model reuses the same
pipelined roofline core as the im2col model with internally-chosen tiles,
standing in for the paper's "calibrated by simulation and synthesis".
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import ConfigSpace
from repro.design_models.base import DesignModel, make_dim, pow2_choices
from repro.design_models.im2col import make_net_space, roofline_latency_power

FIXED_DSB = 64.0   # DRAM->SRAM words/cycle (board property)
FIXED_SDB = 32.0   # SRAM->DRAM words/cycle


def make_dnnweaver_space() -> ConfigSpace:
    return ConfigSpace(
        dims=(
            make_dim("PEN", pow2_choices(4, 512)),
            make_dim("ISS", pow2_choices(128, 8192)),
            make_dim("WSS", pow2_choices(128, 8192)),
            make_dim("OSS", pow2_choices(128, 8192)),
        )
    )


def _greedy_tile(cap: np.ndarray, *factors: np.ndarray) -> np.ndarray:
    """Largest power-of-two scale s.t. prod(factors) * scale <= cap."""
    prod = np.ones_like(cap)
    for f in factors:
        prod = prod * f
    scale = np.maximum(cap / np.maximum(prod, 1.0), 1e-9)
    return np.power(2.0, np.floor(np.log2(np.maximum(scale, 1.0))))


class DnnWeaverModel(DesignModel):
    """Low-dimension design space (4 config dims, |space| = 8*7^3 = 2744).

    Both oracles broadcast over arbitrary leading dims — (B,) flat batches
    or (T, C) task-x-candidate grids for the batched Algorithm 2.
    """

    name = "dnnweaver"

    def __init__(self) -> None:
        self.space = make_dnnweaver_space()
        self.net_space = make_net_space()

    def _derive_tiles(self, net, iss, wss, oss, xp=np):
        dt = np.float64 if xp is np else jnp.float32
        ic, oc, ow, oh, kw, kh = (net[..., i].astype(dt) for i in range(6))
        # template schedule: keep full kernel window; tile channels to fit
        # the weight SRAM, tile the output plane to fit the output SRAM.
        tkw, tkh = kw, kh

        def pow2floor(x):
            return xp.power(2.0, xp.floor(xp.log2(xp.maximum(x, 1.0))))

        tic = xp.maximum(pow2floor(xp.minimum(ic, wss / xp.maximum(kw * kh, 1.0))), 1.0)
        toc = xp.maximum(pow2floor(xp.minimum(
            xp.minimum(oc, oss),
            wss / xp.maximum(tic * kw * kh, 1.0))), 1.0)
        # output tile: square-ish plane tile fitting OSS alongside toc
        plane_cap = xp.maximum(oss / xp.maximum(toc, 1.0), 1.0)
        tow = xp.maximum(xp.minimum(pow2floor(xp.sqrt(plane_cap)), ow), 1.0)
        toh = xp.maximum(xp.minimum(pow2floor(plane_cap / tow), oh), 1.0)
        # input SRAM bounds the im2col patch tile: shrink (toh, tow, tic)
        # in turn (power-of-two halvings) until the patch fits.
        tiles = [toh, tow, tic]
        for j in range(3):
            patch = tiles[2] * tkw * tkh * tiles[1] * tiles[0]
            excess = xp.power(2.0, xp.ceil(xp.log2(
                xp.maximum(patch / xp.maximum(iss, 1.0), 1.0))))
            f = xp.minimum(tiles[j], excess)
            tiles[j] = xp.maximum(tiles[j] / f, 1.0)
        toh, tow, tic = tiles
        return tic, toc, tow, toh, tkw, tkh

    def evaluate(self, net: np.ndarray, config: np.ndarray):
        net = np.asarray(net, np.float64)
        c = np.asarray(config, np.float64)
        pen, iss, wss, oss = (c[..., i] for i in range(4))
        tic, toc, tow, toh, tkw, tkh = self._derive_tiles(net, iss, wss, oss)
        return roofline_latency_power(
            net, pen, FIXED_DSB, FIXED_SDB, iss, wss, oss,
            tic, toc, tow, toh, tkw, tkh,
        )

    def evaluate_jax(self, net, config):
        net = jnp.asarray(net, jnp.float32)
        c = jnp.asarray(config, jnp.float32)
        pen, iss, wss, oss = (c[..., i] for i in range(4))
        tic, toc, tow, toh, tkw, tkh = self._derive_tiles(net, iss, wss, oss, xp=jnp)
        return roofline_latency_power(
            net, pen, FIXED_DSB, FIXED_SDB, iss, wss, oss,
            tic, toc, tow, toh, tkw, tkh,
            xp=jnp,
        )
