"""The DnnWeaver design model (paper §7.1.1).

Systolic-array template in the style of the open-source DnnWeaver v2 code.
Low-dimension design space (Table 1: configurations without '*'): PE number
and the three SRAM sizes.  The mapping (tiling) is derived internally by
the template's own greedy schedule — the user does not control it — and the
DRAM bandwidths are fixed board properties.  The model reuses the same
pipelined roofline core as the im2col model with internally-chosen tiles,
standing in for the paper's "calibrated by simulation and synthesis".
"""
from __future__ import annotations

import numpy as np

from repro.core.encoding import ConfigSpace
from repro.design_models.base import DesignModel, make_dim, pow2_choices
from repro.design_models.im2col import make_net_space, roofline_latency_power

FIXED_DSB = 64.0   # DRAM->SRAM words/cycle (board property)
FIXED_SDB = 32.0   # SRAM->DRAM words/cycle


def make_dnnweaver_space() -> ConfigSpace:
    return ConfigSpace(
        dims=(
            make_dim("PEN", pow2_choices(4, 512)),
            make_dim("ISS", pow2_choices(128, 8192)),
            make_dim("WSS", pow2_choices(128, 8192)),
            make_dim("OSS", pow2_choices(128, 8192)),
        )
    )


def _greedy_tile(cap: np.ndarray, *factors: np.ndarray) -> np.ndarray:
    """Largest power-of-two scale s.t. prod(factors) * scale <= cap."""
    prod = np.ones_like(cap)
    for f in factors:
        prod = prod * f
    scale = np.maximum(cap / np.maximum(prod, 1.0), 1e-9)
    return np.power(2.0, np.floor(np.log2(np.maximum(scale, 1.0))))


class DnnWeaverModel(DesignModel):
    """Low-dimension design space (4 config dims, |space| = 8*7^3 = 2744)."""

    name = "dnnweaver"

    def __init__(self) -> None:
        self.space = make_dnnweaver_space()
        self.net_space = make_net_space()

    def _derive_tiles(self, net: np.ndarray, iss, wss, oss):
        ic, oc, ow, oh, kw, kh = (net[..., i].astype(np.float64) for i in range(6))
        # template schedule: keep full kernel window; tile channels to fit
        # the weight SRAM, tile the output plane to fit the output SRAM.
        tkw, tkh = kw, kh

        def pow2floor(x):
            return np.power(2.0, np.floor(np.log2(np.maximum(x, 1.0))))

        tic = np.maximum(pow2floor(np.minimum(ic, wss / np.maximum(kw * kh, 1.0))), 1.0)
        toc = np.maximum(pow2floor(np.minimum(
            np.minimum(oc, oss),
            wss / np.maximum(tic * kw * kh, 1.0))), 1.0)
        # output tile: square-ish plane tile fitting OSS alongside toc
        plane_cap = np.maximum(oss / np.maximum(toc, 1.0), 1.0)
        tow = np.maximum(np.minimum(pow2floor(np.sqrt(plane_cap)), ow), 1.0)
        toh = np.maximum(np.minimum(pow2floor(plane_cap / tow), oh), 1.0)
        # input SRAM bounds the im2col patch tile: shrink (toh, tow, tic)
        # in turn (power-of-two halvings) until the patch fits.
        tiles = [toh, tow, tic]
        for j in range(3):
            patch = tiles[2] * tkw * tkh * tiles[1] * tiles[0]
            excess = np.power(2.0, np.ceil(np.log2(
                np.maximum(patch / np.maximum(iss, 1.0), 1.0))))
            f = np.minimum(tiles[j], excess)
            tiles[j] = np.maximum(tiles[j] / f, 1.0)
        toh, tow, tic = tiles
        return tic, toc, tow, toh, tkw, tkh

    def evaluate(self, net: np.ndarray, config: np.ndarray):
        net = np.asarray(net, np.float64)
        c = np.asarray(config, np.float64)
        pen, iss, wss, oss = (c[..., i] for i in range(4))
        tic, toc, tow, toh, tkw, tkh = self._derive_tiles(net, iss, wss, oss)
        return roofline_latency_power(
            net, pen, FIXED_DSB, FIXED_SDB, iss, wss, oss,
            tic, toc, tow, toh, tkw, tkh,
        )
