"""TPU-mesh design model — the beyond-paper GANDSE application.

The paper's GAN-DSE engine searches *FPGA accelerator* configurations
against an analytic latency/power model.  Here the same engine is pointed
at THIS framework's distributed-training design space: the "network
parameters" are the transformer workload descriptor and the
"configurations" are the parallelism knobs of launch/mesh.py + train/step
(pods, data-parallel degree, tensor-parallel degree, microbatch, remat,
dtype, gradient compression).  The design model is the same three-term
roofline the dry-run derives (utils/roofline.py), so a configuration found
by the GAN maps 1:1 onto a runnable mesh config.

Objectives (the paper's "latency <= x, power <= y" format):
  latency = roofline-bounded training step time (s)
  power   = cluster board power (W): chips * (idle + dynamic * utilization)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import ConfigSpace
from repro.design_models.base import DesignModel, make_dim, pow2_choices
from repro.utils.roofline import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

DCN_BW = 25e9            # B/s cross-pod per chip
HBM_CAP = 16e9           # bytes per chip (v5e-class)
CHIP_IDLE_W = 150.0
CHIP_DYN_W = 250.0
CHIPS_PER_POD = 256


def make_workload_space() -> ConfigSpace:
    """Net-parameter space: the LM workload descriptor (covers the 10
    assigned archs' magnitudes)."""
    return ConfigSpace(dims=(
        make_dim("LAYERS", (12, 24, 32, 40, 48, 64)),
        make_dim("DMODEL", (768, 1152, 1600, 2048, 3584, 4096, 5120, 7168)),
        make_dim("DFF_MULT", (2, 3, 4, 5)),          # d_ff = mult * d_model
        make_dim("SEQ", (2048, 4096, 8192, 16384, 32768)),
        make_dim("GBATCH", (32, 64, 128, 256, 512)),
        make_dim("VOCAB", (32768, 65536, 131072, 262144)),
    ))


def make_mesh_space() -> ConfigSpace:
    """Configuration space: the parallelism knobs."""
    return ConfigSpace(dims=(
        make_dim("PODS", (1, 2, 4, 8)),
        make_dim("DP", pow2_choices(1, 64)),          # per-pod data axis
        make_dim("TP", pow2_choices(1, 64)),          # per-pod model axis
        make_dim("MICRO", pow2_choices(1, 16)),       # grad-accum microbatches
        make_dim("REMAT", (0, 1)),
        make_dim("BYTES_P", (2, 4)),                  # param dtype
        make_dim("COMPRESS", (1, 4)),                 # DCN grad compression x
    ))


class TpuMeshModel(DesignModel):
    """Analytic 3-term roofline over (workload, mesh config).

    Both oracles broadcast over arbitrary leading dims — (B,) flat batches
    or (T, C) task-x-candidate grids for the batched Algorithm 2.
    """

    name = "tpu_mesh"

    def __init__(self) -> None:
        self.space = make_mesh_space()
        self.net_space = make_workload_space()

    def evaluate(self, net: np.ndarray, config: np.ndarray):
        net = np.asarray(net, np.float64)
        c = np.asarray(config, np.float64)
        return self._evaluate(net, c, xp=np)

    def evaluate_jax(self, net, config):
        net = jnp.asarray(net, jnp.float32)
        c = jnp.asarray(config, jnp.float32)
        return self._evaluate(net, c, xp=jnp)

    def _evaluate(self, net, c, xp):
        layers, dm, ffm, seq, gb, vocab = (net[..., i] for i in range(6))
        pods, dp, tp, micro, remat, bytes_p, comp = (c[..., i] for i in range(7))

        dff = ffm * dm
        n_params = layers * (4 * dm * dm + 3 * dm * dff) + vocab * dm
        chips_per_pod = dp * tp
        chips = pods * chips_per_pod
        tokens = gb * seq

        # --- feasibility ----------------------------------------------------
        feasible = (chips_per_pod <= CHIPS_PER_POD) & (gb % (pods * dp * micro) == 0) \
            & (dm % tp == 0)

        # --- compute term ---------------------------------------------------
        flops = 6.0 * n_params * tokens * (1.0 + 0.33 * remat)
        t_comp = flops / (chips * PEAK_FLOPS_BF16)

        # --- memory term ----------------------------------------------------
        # params+opt per chip (FSDP over dp*tp within a pod)
        state_bytes = n_params * (bytes_p + 8.0) / chips_per_pod
        act_rows = gb / (pods * dp * micro)               # rows resident
        act_bytes = act_rows * seq * dm * 2.0 * layers / tp
        act_bytes = xp.where(remat > 0, act_bytes, act_bytes * 6.0)
        hbm = state_bytes + act_bytes
        feasible &= hbm <= HBM_CAP
        # traffic: weights streamed once per microbatch (+bwd), acts 3x
        traffic = (micro * 3.0 * n_params * bytes_p / chips_per_pod
                   + 6.0 * act_bytes)
        t_mem = traffic / HBM_BW

        # --- collective term --------------------------------------------------
        # Per-CHIP bytes (ring collectives move ~2x the local shard per chip
        # regardless of group size — calibrated against the compiled-HLO
        # roofline of the 16x16 and 4x64 validation runs, see
        # benchmarks/bench_gan_hillclimb.py + EXPERIMENTS.md §Perf C).
        rows_per_chip = gb / xp.maximum(pods * dp * micro, 1.0)
        act_bytes_chip = rows_per_chip * seq * dm * 2.0
        # 4 TP all-reduces per layer, fwd+bwd, every microbatch
        tp_bytes = xp.where(tp > 1,
                            layers * 4.0 * 2.0 * 2.0 * act_bytes_chip * micro,
                            0.0)
        # FSDP all-gather of params each microbatch (fwd+bwd) over dp:
        # each chip receives ~ params/tp per gather
        ag_bytes = xp.where(dp > 1, micro * 2.0 * n_params * bytes_p / tp, 0.0)
        # gradient reduce-scatter/all-gather over dp (ICI)
        gr_bytes = xp.where(dp > 1, 2.0 * n_params * bytes_p / tp, 0.0)
        t_ici = (tp_bytes + ag_bytes + gr_bytes) / ICI_LINK_BW
        # cross-pod gradient all-reduce over DCN (compressed)
        dcn_bytes = xp.where(pods > 1,
                             2.0 * n_params * bytes_p / comp / chips_per_pod, 0.0)
        t_dcn = dcn_bytes / DCN_BW
        t_coll = t_ici + t_dcn

        # --- objectives -------------------------------------------------------
        latency = xp.maximum(xp.maximum(t_comp, t_mem), t_coll)
        util = xp.where(latency > 0, t_comp / xp.maximum(latency, 1e-12), 0.0)
        power = chips * (CHIP_IDLE_W + CHIP_DYN_W * util)

        latency = xp.where(feasible, latency, xp.inf)
        power = xp.where(feasible, power, xp.inf)
        return latency, power
