from repro.design_models.base import DesignModel  # noqa: F401
from repro.design_models.im2col import Im2colModel  # noqa: F401
from repro.design_models.dnnweaver import DnnWeaverModel  # noqa: F401
