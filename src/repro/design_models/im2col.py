"""The `im2col` design model (paper §7.1.1).

Output-stationary accelerator executing CNN layers as im2col GEMMs.  The
latency model is a roofline over three pipelined per-tile phases (load,
compute, write-back); the power model combines a static model (resource
dependent) and a dynamic model (activity dependent).  This is the paper's
high-dimension design space (Table 1, 12 configuration dims here), used to
show GANDSE's advantage on high-dimension large design spaces.

All constants are stated explicitly below — the paper does not publish its
calibration constants; ours are chosen to be physically plausible for a
~200 MHz FPGA implementation and are validated by monotonicity property
tests (more PEs => never slower & never less power-hungry, etc.).
"""
from __future__ import annotations

import contextlib
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import ConfigSpace
from repro.design_models.base import DesignModel, make_dim, pow2_choices

# ---------------------------------------------------------------------------
# Hardware constants (stated calibration, §7.1.1 "verified by simulation and
# synthesis" in the paper; here: plausible FPGA-class constants).
# ---------------------------------------------------------------------------
CLOCK_HZ = 2.0e8           # 200 MHz
E_MAC_J = 2.0e-12          # energy per MAC
E_SRAM_J = 4.0e-12         # energy per SRAM word access
E_DRAM_J = 80.0e-12        # energy per DRAM word transferred
P_STATIC_BASE_W = 0.40     # board + logic leakage
P_STATIC_PE_W = 2.0e-4     # per PE
P_STATIC_SRAM_W = 4.0e-6   # per SRAM word of capacity
P_STATIC_BW_W = 1.5e-3     # per word/cycle of DRAM<->SRAM bandwidth

NET_DIMS = ("IC", "OC", "OW", "OH", "KW", "KH")


def make_net_space() -> ConfigSpace:
    return ConfigSpace(
        dims=(
            make_dim("IC", pow2_choices(16, 256)),
            make_dim("OC", pow2_choices(16, 256)),
            make_dim("OW", pow2_choices(8, 64)),
            make_dim("OH", pow2_choices(8, 64)),
            make_dim("KW", (1, 3, 5)),
            make_dim("KH", (1, 3, 5)),
        )
    )


def make_im2col_space() -> ConfigSpace:
    return ConfigSpace(
        dims=(
            make_dim("PEN", pow2_choices(64, 4096)),       # PE number
            make_dim("SDB", pow2_choices(16, 512)),        # SRAM->DRAM words/cyc
            make_dim("DSB", pow2_choices(16, 512)),        # DRAM->SRAM words/cyc
            make_dim("ISS", pow2_choices(256, 8192)),      # input SRAM words
            make_dim("WSS", pow2_choices(256, 8192)),      # weight SRAM words
            make_dim("OSS", pow2_choices(256, 8192)),      # output SRAM words
            make_dim("TIC", pow2_choices(4, 128)),         # tiling
            make_dim("TOC", pow2_choices(4, 128)),
            make_dim("TOW", pow2_choices(4, 256)),
            make_dim("TOH", pow2_choices(4, 256)),
            make_dim("TKW", (1, 2, 3, 4, 5)),
            make_dim("TKH", (1, 2, 3, 4, 5)),
        )
    )


def _ceil_div(a, b, xp=np):
    return xp.ceil(a / b)


def roofline_latency_power(
    net,
    pen, dsb, sdb, iss, wss, oss, tic, toc, tow, toh, tkw, tkh,
    xp=np,
) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized 3-phase pipelined roofline.  All inputs broadcast over
    arbitrary leading dims (flat (B,) batches or (T, C) grids).

    Returns (latency_seconds, power_watts); infeasible -> latency = +inf.
    `xp` selects the array namespace: `np` (float64, host) or `jnp`
    (float32, traceable) — one formula, two backends, kept in lockstep by
    tests/test_oracle_parity.py.
    """
    dt = np.float64 if xp is np else jnp.float32
    ic, oc, ow, oh, kw, kh = (net[..., i].astype(dt) for i in range(6))

    # effective tile sizes never exceed the real dims
    tic = xp.minimum(tic, ic)
    toc = xp.minimum(toc, oc)
    tow = xp.minimum(tow, ow)
    toh = xp.minimum(toh, oh)
    tkw = xp.minimum(tkw, kw)
    tkh = xp.minimum(tkh, kh)

    n_tiles = (
        _ceil_div(ic, tic, xp) * _ceil_div(oc, toc, xp) * _ceil_div(ow, tow, xp)
        * _ceil_div(oh, toh, xp) * _ceil_div(kw, tkw, xp) * _ceil_div(kh, tkh, xp)
    )
    n_out_tiles = _ceil_div(oc, toc, xp) * _ceil_div(ow, tow, xp) * _ceil_div(oh, toh, xp)

    tile_macs = tic * toc * tow * toh * tkw * tkh
    # --- per-tile phase cycle counts --------------------------------------
    t_comp = _ceil_div(tile_macs, pen, xp)
    in_words = tic * tkw * tkh * tow * toh        # im2col patch matrix tile
    w_words = tic * toc * tkw * tkh
    t_load = _ceil_div(in_words + w_words, dsb, xp)
    out_words = toc * tow * toh                   # written once per out tile
    t_store = _ceil_div(out_words, sdb, xp)

    # 3-stage pipeline: steady state bound by the slowest phase; store only
    # fires on output-tile boundaries so its steady-state weight is scaled.
    store_amort = t_store * (n_out_tiles / n_tiles)
    bottleneck = xp.maximum(xp.maximum(t_load, t_comp), store_amort)
    cycles = bottleneck * xp.maximum(n_tiles - 1.0, 0.0) + t_load + t_comp + t_store

    # --- feasibility -------------------------------------------------------
    feasible = (in_words <= iss) & (w_words <= wss) & (out_words <= oss)
    cycles = xp.where(feasible, cycles, xp.inf)

    # --- power -------------------------------------------------------------
    total_macs = ic * oc * ow * oh * kw * kh
    dram_words = n_tiles * (in_words + w_words) + n_out_tiles * out_words
    sram_words = 2.0 * total_macs + n_out_tiles * out_words
    energy = E_MAC_J * total_macs + E_SRAM_J * sram_words + E_DRAM_J * dram_words
    lat_s = cycles / CLOCK_HZ
    p_static = (
        P_STATIC_BASE_W
        + P_STATIC_PE_W * pen
        + P_STATIC_SRAM_W * (iss + wss + oss)
        + P_STATIC_BW_W * (sdb + dsb)
    )
    ctx = np.errstate(invalid="ignore") if xp is np else contextlib.nullcontext()
    with ctx:
        p_dyn = xp.where(xp.isfinite(lat_s), energy / xp.maximum(lat_s, 1e-12), 0.0)
    power = p_static + p_dyn
    power = xp.where(feasible, power, xp.inf)
    return lat_s, power


class Im2colModel(DesignModel):
    """High-dimension design space (12 config dims, |space| ~ 3.3e9).

    Both oracles broadcast over arbitrary leading dims — (B,) flat batches
    or (T, C) task-x-candidate grids for the batched Algorithm 2.
    """

    name = "im2col"

    def __init__(self) -> None:
        self.space = make_im2col_space()
        self.net_space = make_net_space()

    def evaluate(self, net: np.ndarray, config: np.ndarray):
        net = np.asarray(net, np.float64)
        c = np.asarray(config, np.float64)
        (pen, sdb, dsb, iss, wss, oss, tic, toc, tow, toh, tkw, tkh) = (
            c[..., i] for i in range(12)
        )
        return roofline_latency_power(
            net, pen, dsb, sdb, iss, wss, oss, tic, toc, tow, toh, tkw, tkh
        )

    def evaluate_jax(self, net, config):
        net = jnp.asarray(net, jnp.float32)
        c = jnp.asarray(config, jnp.float32)
        (pen, sdb, dsb, iss, wss, oss, tic, toc, tow, toh, tkw, tkh) = (
            c[..., i] for i in range(12)
        )
        return roofline_latency_power(
            net, pen, dsb, sdb, iss, wss, oss, tic, toc, tow, toh, tkw, tkh,
            xp=jnp,
        )
