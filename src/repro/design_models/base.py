"""Design-model interface (paper §2.1, §5.1).

A design model maps (network parameters, configurations) -> objective
metrics (latency, power).  Implementations must be vectorized over a
leading batch axis and be pure-numpy/jnp so they can score thousands of
candidate configuration sets at once (Algorithm 2 scan).
"""
from __future__ import annotations

import abc
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.encoding import ConfigDim, ConfigSpace


class DesignModel(abc.ABC):
    """Analytic model of the metrics in the objectives."""

    name: str = "base"

    #: the configuration design space (one-hot groups)
    space: ConfigSpace
    #: the network-parameter space (dims sampled for the dataset)
    net_space: ConfigSpace

    @abc.abstractmethod
    def evaluate(self, net: np.ndarray, config: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(..., n_net_dims) values, (..., n_cfg_dims) values -> (latency, power).

        Latency in seconds, power in watts; both shaped like the
        broadcast leading dims.  Leading dims are arbitrary and follow
        numpy broadcasting: (B,) for a flat batch, or e.g. net
        (T, 1, n_net_dims) against config (T, C, n_cfg_dims) -> (T, C) for
        the batched Algorithm 2 (T tasks x C candidates each, one call).
        Infeasible configs (e.g. tile does not fit SRAM) return
        latency = +inf.
        """

    def evaluate_jax(self, net: jnp.ndarray, config: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Pure-jnp twin of `evaluate`, traceable inside jit/scan/vmap.

        Same contract as `evaluate` (broadcast leading dims, infeasible ->
        +inf) but every op is a jax primitive so the oracle can be fused
        into the Algorithm 1 train step and the Algorithm 2 candidate scan
        without a host callback.  Models without a jnp port simply don't
        override this; callers must check `has_jax_oracle` and fall back to
        `jax.pure_callback`.
        """
        raise NotImplementedError(f"{self.name} has no jnp oracle")

    @property
    def has_jax_oracle(self) -> bool:
        """True when this model overrides `evaluate_jax`."""
        return type(self).evaluate_jax is not DesignModel.evaluate_jax

    # convenience -----------------------------------------------------------
    def evaluate_indices(self, net_idx, cfg_idx):
        """Index-space entry point; leading dims broadcast like `evaluate`."""
        net = self.net_space.values_from_indices(net_idx)
        cfg = self.space.values_from_indices(cfg_idx)
        return self.evaluate(net, cfg)

    def evaluate_jax_indices(self, net_idx, cfg_idx):
        """Traceable index-space entry point (choice tables are constants);
        leading dims broadcast like `evaluate_jax`."""
        net = self.net_space.values_from_indices_jax(net_idx)
        cfg = self.space.values_from_indices_jax(cfg_idx)
        return self.evaluate_jax(net, cfg)


def pow2_choices(lo: int, hi: int) -> Tuple[float, ...]:
    out = []
    v = lo
    while v <= hi:
        out.append(float(v))
        v *= 2
    return tuple(out)


def make_dim(name: str, choices) -> ConfigDim:
    return ConfigDim(name=name, choices=tuple(float(c) for c in choices))
