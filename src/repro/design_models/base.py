"""Design-model interface (paper §2.1, §5.1).

A design model maps (network parameters, configurations) -> objective
metrics (latency, power).  Implementations must be vectorized over a
leading batch axis and be pure-numpy/jnp so they can score thousands of
candidate configuration sets at once (Algorithm 2 scan).
"""
from __future__ import annotations

import abc
from typing import Tuple

import numpy as np

from repro.core.encoding import ConfigDim, ConfigSpace


class DesignModel(abc.ABC):
    """Analytic model of the metrics in the objectives."""

    name: str = "base"

    #: the configuration design space (one-hot groups)
    space: ConfigSpace
    #: the network-parameter space (dims sampled for the dataset)
    net_space: ConfigSpace

    @abc.abstractmethod
    def evaluate(self, net: np.ndarray, config: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(B, n_net_dims) values, (B, n_cfg_dims) values -> (latency, power).

        Latency in cycles, power in watts; both (B,).  Infeasible configs
        (e.g. tile does not fit SRAM) return latency = +inf.
        """

    # convenience -----------------------------------------------------------
    def evaluate_indices(self, net_idx, cfg_idx):
        net = self.net_space.values_from_indices(net_idx)
        cfg = self.space.values_from_indices(cfg_idx)
        return self.evaluate(net, cfg)


def pow2_choices(lo: int, hi: int) -> Tuple[float, ...]:
    out = []
    v = lo
    while v <= hi:
        out.append(float(v))
        v *= 2
    return tuple(out)


def make_dim(name: str, choices) -> ConfigDim:
    return ConfigDim(name=name, choices=tuple(float(c) for c in choices))
