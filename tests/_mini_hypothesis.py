"""Seeded-random fallback for `hypothesis` when it is not installed.

Implements exactly the subset this suite uses — ``given``, ``settings``
and the ``integers / floats / lists / tuples / builds / sampled_from``
strategies — by
degrading each ``@given`` property test to ``max_examples`` seeded-random
example runs.  Weaker than real hypothesis (no shrinking, no failure
database, no edge-case bias) but it keeps the property tests collectible
and meaningful on minimal CI images.  ``pip install -r
requirements-dev.txt`` to run the real thing; the test modules prefer it
automatically when importable.
"""
from __future__ import annotations

import zlib
from typing import Callable

import numpy as np


class _Strategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw: Callable):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, allow_nan: bool = False,
               **_kw) -> _Strategy:
        return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = None,
              unique: bool = False) -> _Strategy:
        hi = max_size if max_size is not None else min_size + 8

        def draw(r):
            n = int(r.integers(min_size, hi + 1))
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 100 * (n + 1):
                v = elements.draw(r)
                tries += 1
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out

        return _Strategy(draw)

    @staticmethod
    def tuples(*ss: _Strategy) -> _Strategy:
        return _Strategy(lambda r: tuple(s.draw(r) for s in ss))

    @staticmethod
    def builds(target: Callable, *ss: _Strategy) -> _Strategy:
        return _Strategy(lambda r: target(*(s.draw(r) for s in ss)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda r: pool[int(r.integers(0, len(pool)))])


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(f):
        f._mini_max_examples = max_examples
        return f

    return deco


def given(*ss: _Strategy):
    def deco(f):
        n = getattr(f, "_mini_max_examples", 20)

        def runner():
            # deterministic per-test seed so failures reproduce
            rng = np.random.default_rng(zlib.crc32(f.__name__.encode()))
            for _ in range(n):
                f(*(s.draw(rng) for s in ss))

        # plain no-arg signature so pytest doesn't mistake the generated
        # arguments for fixtures
        runner.__name__ = f.__name__
        runner.__doc__ = f.__doc__
        return runner

    return deco
