"""Baseline repairs + the device-resident comparison-harness contracts.

Pins the four bugfixes (MLP seed, SA violation guard, DRL reward clip,
explorer key overflow — the last in test_explore_batch.py) and the
batched-vs-sequential parity of every baseline's ``explore_tasks``,
including zero-feasible tasks and the host fallback for models without a
jnp oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.drl import VIOL_CLIP, PolicyGradientDRL
from repro.baselines.drl import _violation as drl_violation
from repro.baselines.mlp import LargeMLP
from repro.baselines.random_search import RandomSearch
from repro.baselines.sa import _BIG, SimulatedAnnealing
from repro.baselines.sa import _violation as sa_violation
from repro.core.dse_api import DSEMethod, GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import DSETask, generate_tasks
from repro.design_models.base import DesignModel
from repro.design_models.dnnweaver import DnnWeaverModel


class _InfeasibleModel(DnnWeaverModel):
    """Every config infeasible: the zero-feasible edge case."""

    name = "dnnweaver_infeasible"

    def evaluate(self, net, config):
        lat, pw = super().evaluate(net, config)
        return np.full_like(lat, np.inf), np.full_like(pw, np.inf)

    def evaluate_jax(self, net, config):
        lat, pw = super().evaluate_jax(net, config)
        return jnp.full_like(lat, jnp.inf), jnp.full_like(pw, jnp.inf)


class _HostOnlyModel(DnnWeaverModel):
    """jnp oracle hidden: exercises the sequential host fallback."""

    name = "dnnweaver_host_only"
    evaluate_jax = DesignModel.evaluate_jax


class _InfPowerModel(DnnWeaverModel):
    """Finite latency everywhere, power = +inf unless PEN == 4 (its first
    choice): the finite-latency/non-finite-power corruption case."""

    name = "dnnweaver_inf_power"
    evaluate_jax = DesignModel.evaluate_jax

    def evaluate(self, net, config):
        lat, pw = super().evaluate(net, config)
        lat = np.where(np.isfinite(lat), lat, 1.0)
        pw = np.where(np.asarray(config)[..., 0] == 4.0, pw, np.inf)
        return lat, pw


@pytest.fixture(scope="module")
def model():
    return DnnWeaverModel()


@pytest.fixture(scope="module")
def tasks(model):
    return generate_tasks(model, 4, seed=2)


@pytest.fixture(scope="module")
def mlp(model, small_dataset):
    m = LargeMLP(model, hidden_layers=1, neurons=32,
                 explorer_cfg=ExplorerConfig(prob_threshold=0.1,
                                             max_candidates=128))
    m.train(n_data=0, iters=1, seed=0, ds=small_dataset(model, n=256))
    return m


@pytest.fixture(scope="module")
def drl(model, small_dataset):
    m = PolicyGradientDRL(model, hidden_layers=1, neurons=32, rollout_len=8,
                          batch_tasks=16)
    m.train(n_data=0, iters=2, seed=0, ds=small_dataset(model, n=256))
    return m


def _assert_selection_equal(name, i, sa, sb):
    assert sa.n_candidates == sb.n_candidates, (name, i)
    assert (sa.cfg_idx is None) == (sb.cfg_idx is None), (name, i)
    if sa.cfg_idx is not None:
        np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx,
                                      err_msg=f"{name}[{i}]")
    assert sa.latency == sb.latency and sa.power == sb.power, (name, i)
    assert sa.satisfied == sb.satisfied, (name, i)


# ---------------------------------------------------------------------------
# DSEMethod protocol
# ---------------------------------------------------------------------------
def test_all_methods_speak_the_protocol(model):
    methods = (GANDSE(model), LargeMLP(model), PolicyGradientDRL(model),
               SimulatedAnnealing(model), RandomSearch(model))
    names = set()
    for m in methods:
        assert isinstance(m, DSEMethod), type(m).__name__
        names.add(m.method_name)
    assert len(names) == 5
    # model-free methods accept the shared training call as a no-op
    assert SimulatedAnnealing(model).train(n_data=0, iters=0) is not None
    assert RandomSearch(model).train(n_data=0, iters=0) is not None


# ---------------------------------------------------------------------------
# LargeMLP: seed bugfix + batched parity
# ---------------------------------------------------------------------------
def test_mlp_explore_honors_seed(mlp, tasks):
    """`explore` used to ignore `seed` and run a single zero-noise forward;
    it must now average noise_samples seeded draws like the Explorer."""
    net, lo, po = tasks.net_idx[0], tasks.lat_obj[0], tasks.pow_obj[0]
    p0 = np.asarray(mlp.generator_probs_device(net, lo, po, seed=0))
    p0b = np.asarray(mlp.generator_probs_device(net, lo, po, seed=0))
    p1 = np.asarray(mlp.generator_probs_device(net, lo, po, seed=1))
    np.testing.assert_array_equal(p0, p0b)      # same seed: deterministic
    assert not np.array_equal(p0, p1)           # seeds differ: probs differ
    a = mlp.explore(net, lo, po, seed=0)
    b = mlp.explore(net, lo, po, seed=0)
    _assert_selection_equal("mlp_seed", 0, a.selection, b.selection)


def test_mlp_explore_tasks_parity(mlp, tasks):
    batched = mlp.explore_tasks(tasks, seed=3)
    seq = mlp.explore_tasks(tasks, seed=3, batched=False)
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal("mlp", i, a.selection, b.selection)
    assert any(r.selection.cfg_idx is not None for r in batched)


def test_mlp_explore_tasks_parity_zero_feasible(mlp, tasks, small_dataset):
    infeasible = LargeMLP(_InfeasibleModel(), hidden_layers=1, neurons=32,
                          explorer_cfg=mlp.explorer_cfg)
    infeasible.ds = small_dataset(DnnWeaverModel(), n=256)
    infeasible.params = mlp.params          # same space: params are shared
    batched = infeasible.explore_tasks(tasks, seed=3)
    seq = infeasible.explore_tasks(tasks, seed=3, batched=False)
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal("mlp_inf", i, a.selection, b.selection)
        assert a.selection.cfg_idx is None and not a.selection.satisfied
        assert a.selection.n_candidates > 0


# ---------------------------------------------------------------------------
# SimulatedAnnealing: violation guard bugfix + batched parity
# ---------------------------------------------------------------------------
def test_sa_violation_guards_both_metrics():
    """Only latency was guarded: finite-latency/non-finite-power configs
    leaked inf/NaN into the accept/best comparisons (NaN power even scored
    as zero violation, i.e. 'satisfied')."""
    assert sa_violation(1.0, np.inf, 1.0, 1.0) == _BIG
    assert sa_violation(1.0, np.nan, 1.0, 1.0) == _BIG
    assert sa_violation(np.nan, 1.0, 1.0, 1.0) == _BIG
    assert sa_violation(1.0, 1.0, 2.0, 2.0) == 0.0


def test_sa_escapes_infeasible_power_region(model):
    """With every non-PEN=4 config at power=+inf, the pre-fix accept rule
    compared inf/NaN energies and froze on its (infeasible) initial config
    forever; the guarded violation random-walks out and satisfies."""
    stub = _InfPowerModel()
    rng = np.random.default_rng(0)
    net = stub.net_space.sample_indices(rng, 1)[0]
    # generous objectives: any feasible (PEN=4) config satisfies them
    all_cfg = np.stack(np.meshgrid(
        *[np.arange(d.n) for d in stub.space.dims], indexing="ij"),
        axis=-1).reshape(-1, stub.space.n_dims)
    lat, pw = stub.evaluate_indices(np.broadcast_to(net, (len(all_cfg), net.size)),
                                    all_cfg)
    ok = np.isfinite(pw)
    assert ok.any()
    lo = float(lat[ok].max() * 1.05)
    po = float(pw[ok].max() * 1.05)
    res = SimulatedAnnealing(stub).explore(net, lo, po, seed=7)
    assert res.satisfied
    assert res.selection.cfg_idx[0] == 0        # found the PEN=4 region
    assert np.isfinite(res.selection.power)


def test_sa_explore_tasks_parity(model, tasks):
    sa = SimulatedAnnealing(model)
    batched = sa.explore_tasks(tasks, seed=5)
    for i in range(len(batched)):
        r = sa.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                       seed=5 + i)
        _assert_selection_equal("sa", i, batched[i].selection, r.selection)
    assert all(r.selection.cfg_idx is not None for r in batched)


def test_sa_explore_tasks_parity_zero_feasible(tasks):
    sa = SimulatedAnnealing(_InfeasibleModel())
    batched = sa.explore_tasks(tasks, seed=5)
    for i in range(len(batched)):
        r = sa.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                       seed=5 + i)
        _assert_selection_equal("sa_inf", i, batched[i].selection, r.selection)
        # SA reports its best visited config even when nothing is feasible
        assert batched[i].selection.cfg_idx is not None
        assert not batched[i].selection.satisfied
        assert batched[i].selection.latency == np.inf
        # every proposal was evaluated: no early satisfied exit
        assert batched[i].selection.n_candidates == sa.max_steps + 1


# ---------------------------------------------------------------------------
# PolicyGradientDRL: reward clip bugfix + batched parity
# ---------------------------------------------------------------------------
def test_drl_rewards_are_bounded():
    """nan_to_num used to map infeasible configs to ~1e9 violation, so one
    infeasible->feasible step rewarded ~1e9 and swamped the moving baseline
    and advantage normalization; violations now clip at VIOL_CLIP/metric."""
    lo = po = np.array([1.0])
    v_inf = drl_violation(np.array([np.inf]), np.array([np.inf]), lo, po)
    assert float(v_inf[0]) == 2 * VIOL_CLIP
    # NaN used to count as ZERO violation (nan_to_num(nan=0.0) undershot lo)
    v_nan = drl_violation(np.array([np.nan]), np.array([1.0]), lo, po)
    assert float(v_nan[0]) == VIOL_CLIP
    # worst one-step reward: most-infeasible -> satisfied (+ bonus)
    sat_bonus = PolicyGradientDRL.sat_bonus
    reward = float(v_inf[0] - 0.0) + sat_bonus * 1.0
    assert reward == 2 * VIOL_CLIP + sat_bonus == 22.0
    # feasible metrics are exact below the clip
    v = drl_violation(np.array([1.5]), np.array([3.0]), np.array([1.0]),
                      np.array([2.0]))
    assert np.isclose(float(v[0]), 0.5 + 0.5)


def test_drl_explore_tasks_parity(drl, tasks):
    batched = drl.explore_tasks(tasks, seed=4)
    for i in range(len(batched)):
        r = drl.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                        seed=4 + i)
        _assert_selection_equal("drl", i, batched[i].selection, r.selection)
        assert batched[i].selection.n_candidates == drl.rollout_len + 1


def test_drl_explore_tasks_parity_zero_feasible(drl, tasks, small_dataset):
    inf_drl = PolicyGradientDRL(_InfeasibleModel(), hidden_layers=1,
                                neurons=32, rollout_len=8)
    inf_drl.ds = small_dataset(DnnWeaverModel(), n=256)
    inf_drl.params = drl.params
    batched = inf_drl.explore_tasks(tasks, seed=4)
    for i in range(len(batched)):
        r = inf_drl.explore(tasks.net_idx[i], tasks.lat_obj[i],
                            tasks.pow_obj[i], seed=4 + i)
        _assert_selection_equal("drl_inf", i, batched[i].selection,
                                r.selection)
        assert not batched[i].selection.satisfied
        assert batched[i].selection.latency == np.inf


# ---------------------------------------------------------------------------
# RandomSearch: batched parity
# ---------------------------------------------------------------------------
def test_random_search_explore_tasks_parity(model, tasks):
    rs = RandomSearch(model, n_samples=64)
    batched = rs.explore_tasks(tasks, seed=6)
    seq = rs.explore_tasks(tasks, seed=6, batched=False)
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal("rs", i, a.selection, b.selection)


# ---------------------------------------------------------------------------
# host fallback (models without a jnp oracle)
# ---------------------------------------------------------------------------
def test_baselines_fall_back_without_jax_oracle(mlp, drl, tasks,
                                                small_dataset):
    host = _HostOnlyModel()
    assert not host.has_jax_oracle
    ds = small_dataset(DnnWeaverModel(), n=256)

    m = LargeMLP(host, hidden_layers=1, neurons=32,
                 explorer_cfg=mlp.explorer_cfg)
    m.ds, m.params = ds, mlp.params
    d = PolicyGradientDRL(host, hidden_layers=1, neurons=32, rollout_len=8)
    d.ds, d.params = ds, drl.params
    sa = SimulatedAnnealing(host)
    rs = RandomSearch(host, n_samples=32)
    for name, method in [("mlp", m), ("drl", d), ("sa", sa), ("rs", rs)]:
        res = method.explore_tasks(tasks, seed=9)
        assert len(res) == tasks.net_idx.shape[0], name
        assert all(np.isfinite(r.dse_seconds) for r in res), name
        # even a FORCED batched route falls back (the GANDSE rule), rather
        # than crashing inside jit on the missing jnp oracle
        forced = method.explore_tasks(tasks, seed=9, batched=True)
        for i, (a, b) in enumerate(zip(forced, res)):
            _assert_selection_equal(f"{name}_forced", i, a.selection,
                                    b.selection)
    # the host fallback still finds configurations
    assert all(r.selection.cfg_idx is not None
               for r in sa.explore_tasks(tasks, seed=9))
