import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_gan_cfg():
    """Factory for the shared reduced-scale GANConfig used across tier-1
    GAN tests: same algorithm, CI-sized networks."""
    from repro.core.gan import GANConfig

    def make(model, *, layers=1, neurons=32, batch_size=64, lr=1e-3, **kw):
        return GANConfig(n_net=model.net_space.n_dims, **kw).scaled(
            layers=layers, neurons=neurons, batch_size=batch_size, lr=lr)

    return make


@pytest.fixture(scope="session")
def small_dataset():
    """Session-cached small datasets so multiple modules share one
    generation pass per (model, n, seed).  Returns a fresh copy each
    call: tests mutate datasets in place (ds.latency[:] = ...), which
    must not leak through the session cache."""
    import dataclasses

    from repro.dataset.generator import generate_dataset

    cache = {}

    def make(model, n=512, seed=0):
        key = (model.name, n, seed)
        if key not in cache:
            cache[key] = generate_dataset(model, n, seed=seed)
        ds = cache[key]
        return dataclasses.replace(
            ds, net_idx=ds.net_idx.copy(), cfg_idx=ds.cfg_idx.copy(),
            latency=ds.latency.copy(), power=ds.power.copy())

    return make
