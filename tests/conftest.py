import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def no_recompile():
    """`with no_recompile():` — fail the test if the block triggers any
    XLA compilation (wrap warm hot paths only).  Pass `allowed=n` to
    permit a known number.  See tools/lint/recompile_guard.py."""
    from tools.lint.recompile_guard import assert_no_recompiles
    return assert_no_recompiles


@pytest.fixture
def track_compiles():
    """`with track_compiles() as rec:` — observe `rec.count` XLA
    compilations triggered by the block."""
    from tools.lint import recompile_guard
    return recompile_guard.track_compiles


@pytest.fixture(scope="session")
def tiny_gan_cfg():
    """Factory for the shared reduced-scale GANConfig used across tier-1
    GAN tests: same algorithm, CI-sized networks."""
    from repro.core.gan import GANConfig

    def make(model, *, layers=1, neurons=32, batch_size=64, lr=1e-3, **kw):
        return GANConfig(n_net=model.net_space.n_dims, **kw).scaled(
            layers=layers, neurons=neurons, batch_size=batch_size, lr=lr)

    return make


@pytest.fixture(scope="session")
def small_dataset():
    """Session-cached small datasets so multiple modules share one
    generation pass per (model, n, seed).  Returns a fresh copy each
    call: tests mutate datasets in place (ds.latency[:] = ...), which
    must not leak through the session cache."""
    import dataclasses

    from repro.dataset.generator import generate_dataset

    cache = {}

    def make(model, n=512, seed=0):
        key = (model.name, n, seed)
        if key not in cache:
            cache[key] = generate_dataset(model, n, seed=seed)
        ds = cache[key]
        return dataclasses.replace(
            ds, net_idx=ds.net_idx.copy(), cfg_idx=ds.cfg_idx.copy(),
            latency=ds.latency.copy(), power=ds.power.copy())

    return make
