"""Properties of the high-level DSE API surface.

- `parse_network` snapping: always lands on a legal sampled value and a
  second parse of the snapped description is a fixed point (idempotent),
  for arbitrary float descriptions;
- `explore_tasks(batched=True/False)` agree on the satisfied flag for
  random task batches (the routing knob never changes the verdict);
- `summarize` is defined and warning-silent on empty and all-unsatisfied
  result lists (regression: `np.mean([])` used to emit a RuntimeWarning
  and `dse_time_s` went NaN).
"""
import functools
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

import jax

from repro.core import gan as G
from repro.core.dse_api import (DSEResult, GANDSE, parse_network, summarize)
from repro.core.explorer import ExplorerConfig
from repro.core.selector import Selection
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel

_MODEL = DnnWeaverModel()


# ---------------------------------------------------------------------------
# parse_network properties
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_parse_network_snaps_to_legal_values_idempotently(seed, spread):
    """Random float descriptions (log-uniform over ~2x beyond the sampled
    range either side) snap onto legal choices; re-parsing the snapped
    values returns the same indices (a fixed point)."""
    rng = np.random.default_rng(seed)
    desc = {}
    for d in _MODEL.net_space.dims:
        lo, hi = min(d.choices), max(d.choices)
        val = np.exp(rng.uniform(np.log(lo / 2), np.log(hi * 2)))
        # `spread` sometimes lands values exactly on a legal choice
        desc[d.name] = float(d.choices[rng.integers(d.n)]) \
            if spread < 0.3 else float(val)
    idx = parse_network(desc, _MODEL)
    assert idx.shape == (_MODEL.net_space.n_dims,)
    vals = _MODEL.net_space.values_from_indices(idx[None])[0]
    for d, v, i in zip(_MODEL.net_space.dims, vals, idx):
        assert v in d.choices, (d.name, v)
        assert 0 <= i < d.n
    snapped_desc = {d.name: float(v)
                    for d, v in zip(_MODEL.net_space.dims, vals)}
    np.testing.assert_array_equal(parse_network(snapped_desc, _MODEL), idx)


# ---------------------------------------------------------------------------
# batched/unbatched satisfied-flag agreement
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _engine():
    """Module-lazy engine: `@given` wrappers (mini-hypothesis) take no
    pytest fixtures, so the property tests share this cached build."""
    from repro.dataset.generator import generate_dataset

    cfg = G.GANConfig(n_net=_MODEL.net_space.n_dims).scaled(
        layers=1, neurons=32, batch_size=64)
    g = GANDSE(_MODEL, cfg,
               ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    g.attach(generate_dataset(_MODEL, 256, seed=0),
             G.init_generator(jax.random.PRNGKey(3), cfg, _MODEL.space))
    return g


@pytest.fixture(scope="module")
def engine():
    return _engine()


@given(st.integers(0, 10_000), st.floats(1.0, 2.5))
@settings(max_examples=5, deadline=None)
def test_explore_tasks_batched_flag_agreement(seed, slack_hi):
    """For random task batches, the batched device route and the
    sequential host loop agree on every satisfied flag.  (T is fixed so
    all examples share one compiled program.)"""
    g = _engine()
    tasks = generate_tasks(_MODEL, 4, seed=seed, slack=(1.0, slack_hi))
    batched = g.explore_tasks(tasks, seed=seed % 97, batched=True)
    seq = g.explore_tasks(tasks, seed=seed % 97, batched=False)
    assert [r.satisfied for r in batched] == [r.satisfied for r in seq]


def test_explore_tasks_accepts_per_row_seed_array(engine):
    """The (T,) seed-array form (what the serving layer uses) equals the
    corresponding scalar-seed single explorations."""
    tasks = generate_tasks(_MODEL, 4, seed=2)
    seeds = np.array([41, 7, 1_000_003, 13], np.int64)
    batched = engine.explore_tasks(tasks, seed=seeds)
    for i in range(4):
        single = engine.explore(tasks.net_idx[i], tasks.lat_obj[i],
                                tasks.pow_obj[i], seed=int(seeds[i]))
        a, b = batched[i].selection, single.selection
        assert a.n_candidates == b.n_candidates
        assert (a.cfg_idx is None) == (b.cfg_idx is None)
        if a.cfg_idx is not None:
            np.testing.assert_array_equal(a.cfg_idx, b.cfg_idx)
        assert (a.latency, a.power, a.satisfied) == \
               (b.latency, b.power, b.satisfied)


def test_baseline_routes_accept_per_row_seed_arrays():
    """The DSEMethod protocol's (T,) seed-array form must hold for the
    baselines' host fallbacks too (the serving layer dispatches arrays):
    row i equals a standalone explore with seed[i], so results never
    depend on micro-batch placement."""
    from repro.baselines.random_search import RandomSearch
    from repro.baselines.sa import SimulatedAnnealing

    tasks = generate_tasks(_MODEL, 3, seed=2)
    seeds = np.array([23, 5, 1_000_003], np.int64)
    for method in (RandomSearch(_MODEL, n_samples=32),
                   SimulatedAnnealing(_MODEL)):
        # batched=False runs the host loop; force the same route on the
        # single-task side (SA's bare explore would auto-route to device)
        kw = {"use_jax": False} if hasattr(method, "_explore_host") else {}
        rows = method.explore_tasks(tasks, seed=seeds, batched=False)
        for i in range(3):
            single = method.explore(tasks.net_idx[i], tasks.lat_obj[i],
                                    tasks.pow_obj[i], seed=int(seeds[i]),
                                    **kw)
            a, b = rows[i].selection, single.selection
            assert (a.cfg_idx is None) == (b.cfg_idx is None), method
            if a.cfg_idx is not None:
                np.testing.assert_array_equal(a.cfg_idx, b.cfg_idx)
            assert (a.latency, a.power, a.satisfied) == \
                   (b.latency, b.power, b.satisfied), method


# ---------------------------------------------------------------------------
# summarize edge cases
# ---------------------------------------------------------------------------
def _unsat(n_candidates=0):
    return DSEResult(Selection(None, np.inf, np.inf, False, n_candidates),
                     1e-3, 2.0, 0.5)


def test_summarize_empty_is_defined_and_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # any RuntimeWarning -> failure
        s = summarize([])
    assert s["n_tasks"] == 0 and s["n_satisfied"] == 0
    assert s["dse_time_s"] == 0.0 and s["n_candidates"] == 0.0
    assert np.isnan(s["improvement_ratio"])
    assert np.isnan(s["lat_err_std"]) and np.isnan(s["pow_err_std"])


def test_summarize_all_unsatisfied_is_defined_and_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s = summarize([_unsat(), _unsat(3)])
    assert s["n_tasks"] == 2 and s["n_satisfied"] == 0
    assert s["dse_time_s"] == 0.5 and s["n_candidates"] == 1.5
    assert np.isnan(s["improvement_ratio"])
    # every selection infeasible -> no finite errors to spread
    assert np.isnan(s["lat_err_std"]) and np.isnan(s["pow_err_std"])


def test_summarize_mixed_still_reports(engine):
    tasks = generate_tasks(_MODEL, 4, seed=2)
    s = summarize([engine.explore(tasks.net_idx[i], tasks.lat_obj[i],
                                  tasks.pow_obj[i], seed=7 + i)
                   for i in range(4)])
    assert s["n_tasks"] == 4
    assert s["dse_time_s"] > 0.0
