"""Tier-1 tests for the online improvement loop (`repro.serve.online`).

Covers the loop's pieces in isolation (hard-task buffer, miner, replay
region) and the wired-up cycle run synchronously against a live front
end: one `run_generation()` must train, checkpoint, hot-swap (params
generation bumped, cache invalidated), and — when the just-written
checkpoint is corrupted before the swap reads it back — fall back to the
previous good generation and keep serving.
"""
import jax
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.dse_api import DSEResult, GANDSE
from repro.core.explorer import ExplorerConfig
from repro.core.selector import Selection
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.serve import (DSEServer, HardReplay, HardTaskBuffer, OnlineConfig,
                         OnlineLoop, ServeConfig, ServeFrontend,
                         corrupt_checkpoint, mine_hard_examples)
from repro.serve.request import SOURCE_DISPATCH, SOURCE_FAILED, DSEResponse


# ---------------------------------------------------------------------------
# HardTaskBuffer: harvest policy, dedup, bounded eviction
# ---------------------------------------------------------------------------
def _resp(rid, *, satisfied, lat_obj=1.0, pow_obj=1.0, seed=0, net=None,
          source=SOURCE_DISPATCH, failed=False):
    net = np.full(3, rid, np.int64) if net is None else net
    result = None if failed else DSEResult(
        Selection(np.zeros(3, np.int64), 2.0, 2.0, satisfied, 1),
        lat_obj, pow_obj, 0.0)
    return DSEResponse(rid, "m", result,
                       SOURCE_FAILED if failed else source,
                       net_idx=None if failed else net,
                       seed=None if failed else seed)


def test_buffer_admits_only_unsatisfied_answers():
    buf = HardTaskBuffer(capacity=8)
    assert buf.offer(_resp(1, satisfied=False))          # the hard case
    assert not buf.offer(_resp(2, satisfied=True))       # solved: not hard
    assert not buf.offer(_resp(3, satisfied=False, failed=True))  # no result
    assert len(buf) == 1
    s = buf.stats()
    assert (s["offered"], s["admitted"]) == (3, 1)


def test_buffer_dedups_on_cache_key():
    buf = HardTaskBuffer(capacity=8)
    net = np.array([1, 2, 3], np.int64)
    assert buf.offer(_resp(1, satisfied=False, net=net, seed=7))
    # same task identity, new rid (a resubmission): harvested once
    assert not buf.offer(_resp(2, satisfied=False, net=net, seed=7))
    # different seed = different cache key = a distinct hard task
    assert buf.offer(_resp(3, satisfied=False, net=net, seed=8))
    assert len(buf) == 2
    assert buf.stats()["deduped"] == 1


def test_buffer_evicts_oldest_and_drains_to_tasks():
    buf = HardTaskBuffer(capacity=4)
    for i in range(6):
        assert buf.offer(_resp(i, satisfied=False, lat_obj=float(i + 1)))
    assert len(buf) == 4
    assert buf.stats()["evicted"] == 2
    tasks = buf.take_all()
    # newest traffic survives: tasks 2..5 (lat_obj 3..6)
    assert tasks is not None and len(tasks) == 4
    assert sorted(tasks.lat_obj.tolist()) == [3.0, 4.0, 5.0, 6.0]
    assert tasks.net_idx.shape == (4, 3)
    assert len(buf) == 0 and buf.take_all() is None
    assert buf.stats()["drained"] == 4


# ---------------------------------------------------------------------------
# mine_hard_examples: Algorithm 1 rows near the objective frontier
# ---------------------------------------------------------------------------
def test_mined_rows_are_valid_training_samples():
    model = DnnWeaverModel()
    tasks = generate_tasks(model, 4, seed=5, slack=(1.0, 1.2))
    mined = mine_hard_examples(model, tasks, n_samples=64, per_task=3,
                               rng=np.random.default_rng(1))
    assert mined is not None
    net, cfg, lat, pw = mined
    assert 1 <= lat.shape[0] <= 4 * 3
    assert net.shape[0] == cfg.shape[0] == lat.shape[0] == pw.shape[0]
    assert np.all(np.isfinite(lat)) and np.all(np.isfinite(pw))
    # each row's recorded metrics are the design model's own outputs for
    # (net, cfg) — a valid (objective, witness) pair as-is
    lat2, pw2 = model.evaluate_indices(net, cfg)
    np.testing.assert_allclose(np.asarray(lat2), lat)
    np.testing.assert_allclose(np.asarray(pw2), pw)


# ---------------------------------------------------------------------------
# HardReplay: fixed shapes across generations
# ---------------------------------------------------------------------------
def test_replay_region_keeps_dataset_shape_constant(small_dataset):
    model = DnnWeaverModel()
    base = small_dataset(model, n=128)
    rep = HardReplay(base, capacity=8, seed=0)
    n0 = rep.dataset().n
    assert n0 == base.n + 8
    # 11 rows into capacity 8: round-robin keeps the newest 8
    marked = 1000.0 + np.arange(11)
    assert rep.mix_in(base.net_idx[:11], base.cfg_idx[:11],
                      marked, base.power[:11]) == 11
    d = rep.dataset()
    assert d.n == n0                        # shape never moves (zero retrace)
    tail = sorted(d.latency[base.n:].tolist())
    assert tail == marked[3:].tolist()      # rows 8..10 overwrote 0..2
    assert rep.absorbed == 11
    # base normalization contract untouched
    np.testing.assert_array_equal(d.net_idx[:base.n], base.net_idx)


# ---------------------------------------------------------------------------
# the wired-up cycle, run synchronously
# ---------------------------------------------------------------------------
def _stack(tiny_gan_cfg, small_dataset, key=0):
    model = DnnWeaverModel()
    cfg = tiny_gan_cfg(model)
    eng = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.1,
                                            max_candidates=64))
    ds = small_dataset(model, n=256)
    eng.attach(ds, G.init_generator(
        jax.random.fold_in(jax.random.PRNGKey(key), 3), cfg, model.space))
    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(eng)
    return model, eng, srv


def _push_hard_wave(fe, model, n=12, seed=3, req_seed=100):
    # slack (1.0, 1.0): objectives sit exactly on sampled design points, so
    # a 64-candidate random-init generator misses most of them — guaranteed
    # harvest material
    tasks = generate_tasks(model, n, seed=seed, slack=(1.0, 1.0))
    futs = [fe.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                      tasks.pow_obj[i], seed=req_seed + i) for i in range(n)]
    responses = [f.result(timeout=120) for f in futs]
    assert all(r.ok for r in responses)
    return tasks


def test_online_generation_trains_swaps_and_invalidates(
        tiny_gan_cfg, small_dataset, tmp_path):
    model, eng, srv = _stack(tiny_gan_cfg, small_dataset)
    ocfg = OnlineConfig(min_hard=4, train_iters=2, mine_samples=64,
                        replay_capacity=16, seed=0)
    with ServeFrontend(srv) as fe:
        loop = OnlineLoop(fe, model.name, str(tmp_path), cfg=ocfg)
        tasks = _push_hard_wave(fe, model)
        assert loop.buffer.stats()["admitted"] >= 1
        gen0 = srv.params_generation(model.name)

        assert loop.run_generation()         # synchronous: no trainer thread

        assert loop.generation == 1 and loop.serving_step == 1
        assert loop.counters["swaps"] == 1
        assert loop.counters["swap_fallbacks"] == 0
        assert loop.counters["mined_rows"] >= 1
        assert loop.ckpt.steps() == [1]
        # the swap is visible to the serving tier: params generation bumped,
        # the model's cache entries dropped
        assert srv.params_generation(model.name) == gen0 + 1
        assert srv.summary()["cache"]["invalidations"].get(model.name, 0) >= 1
        # and serving continues on the new generation
        f = fe.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                      tasks.pow_obj[0], seed=999)
        assert f.result(timeout=120).ok
        m = loop.metrics()
        assert m["generation"] == 1 and m["last_error"] is None


def test_corrupt_checkpoint_falls_back_to_previous_generation(
        tiny_gan_cfg, small_dataset, tmp_path):
    model, eng, srv = _stack(tiny_gan_cfg, small_dataset)
    params0 = eng.g_params
    ocfg = OnlineConfig(min_hard=4, train_iters=2, mine_samples=64,
                        replay_capacity=16, seed=0,
                        # damage every post-gen-0 save before the swap's
                        # read-back — the torn-save-during-hot-swap scenario
                        post_checkpoint=lambda sdir: corrupt_checkpoint(sdir))
    with ServeFrontend(srv) as fe:
        loop = OnlineLoop(fe, model.name, str(tmp_path), cfg=ocfg)
        loop.start()          # writes the generation-0 fallback checkpoint
        loop.stop()
        assert loop.ckpt.steps() == [0]

        tasks = _push_hard_wave(fe, model)
        assert loop.run_generation()
        # trained generation 1, but its checkpoint would not survive a
        # crash — so generation 0 serves instead of unrecoverable params
        assert loop.generation == 1
        assert loop.counters["swap_fallbacks"] == 1
        assert loop.serving_step == 0
        assert loop.counters["swaps"] == 1   # swapped, to the good step
        # the attached params are bit-exactly generation 0's
        for a, b in zip(jax.tree_util.tree_leaves(eng.g_params),
                        jax.tree_util.tree_leaves(params0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # serving never stopped
        f = fe.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                      tasks.pow_obj[0], seed=999)
        assert f.result(timeout=120).ok


def test_raising_listener_is_counted_not_fatal(
        tiny_gan_cfg, small_dataset):
    model, eng, srv = _stack(tiny_gan_cfg, small_dataset)
    with ServeFrontend(srv) as fe:
        fe.add_response_listener(lambda r: 1 / 0)
        t = generate_tasks(model, 1, seed=9)
        f = fe.submit(model.name, t.net_idx[0], t.lat_obj[0], t.pow_obj[0],
                      seed=5)
        assert f.result(timeout=120).ok      # the response still resolves
        fm = fe.metrics()["frontend"]
        assert fm["listener_errors"] == 1
        assert "ZeroDivisionError" in fm["last_listener_error"]
