"""Task-mesh sharding parity and the three bugfixes riding this PR.

Single-device-safe tests (always run):
- `train/shardings` regression: meshes without a 'pod'/'data' axis (or
  holding them at size 1) must never emit `PartitionSpec((), ...)` or
  reference axes the mesh lacks;
- `make_host_mesh` shape/axes override and its validation errors;
- `MicroBatcher` queue pruning, targeted-pop rotation, and shard-multiple
  sizing.

Multi-device tests (skipped below 4 devices — run them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``): every batched
DSE route (GANDSE, SA, DRL, LargeMLP) returns bit-identical Selections
under an active task mesh, including ragged task counts; Algorithm 1
training matches single-device up to float reduction order; and the
serving stack end-to-end dispatches shard-multiple batches with
unchanged responses.
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import gan as G
from repro.core import shard
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import DSETask, generate_tasks
from repro.design_models.im2col import Im2colModel
from repro.launch.mesh import make_host_mesh
from repro.serve.batcher import MicroBatcher
from repro.serve.request import DSERequest
from repro.train import shardings as SH

N_DEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < N_DEV,
    reason=f"needs >= {N_DEV} devices; run with "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={N_DEV}")


@pytest.fixture(scope="module")
def model():
    return Im2colModel()


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < N_DEV:
        pytest.skip("multi-device only")
    return make_host_mesh()


def _attached(model, tiny_gan_cfg, small_dataset, seed=3):
    cfg = tiny_gan_cfg(model)
    g = GANDSE(model, cfg,
               ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(model, n=256)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(seed), cfg, model.space))
    return g


def _assert_results_equal(tag, a, b):
    assert len(a) == len(b), tag
    for i, (ra, rb) in enumerate(zip(a, b)):
        sa, sb = ra.selection, rb.selection
        assert sa.n_candidates == sb.n_candidates, (tag, i)
        assert (sa.cfg_idx is None) == (sb.cfg_idx is None), (tag, i)
        if sa.cfg_idx is not None:
            np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx,
                                          err_msg=f"{tag}[{i}]")
        assert sa.latency == sb.latency and sa.power == sb.power, (tag, i)
        assert sa.satisfied == sb.satisfied, (tag, i)


# ---------------------------------------------------------------------------
# bugfix regressions (single-device safe)
# ---------------------------------------------------------------------------
def _spec_axes(spec):
    """Flatten every axis name a PartitionSpec mentions."""
    out = []
    for entry in spec:
        if entry is None:
            continue
        assert entry != (), f"PartitionSpec holds an empty tuple: {spec}"
        out.extend(entry if isinstance(entry, tuple) else (entry,))
    return out


def test_specs_on_model_only_mesh_never_name_absent_axes():
    """Regression: a mesh without 'pod'/'data' used to get
    ``P((), ...)`` (empty batch-axes tuple leaking through ``_div``'s
    vacuous size-1 fallback) and specs naming the absent 'data' axis."""

    class ModelOnlyMesh:
        shape = {"model": 8}
        devices = np.empty((8,), object)

    mesh = ModelOnlyMesh()
    act = SH.activation_spec(mesh, batch=32, d_model=512)
    assert act == P(None, None, "model"), act
    st = SH.state_spec((4, 32, 4096, 8, 64), mesh, batch=32)
    for ax in _spec_axes(act) + _spec_axes(st):
        assert ax in mesh.shape, (act, st)


def test_specs_drop_size1_mesh_axes():
    """A size-1 'data' axis (the default 1-device host mesh) shards
    nothing: specs must replicate rather than name it."""

    class OneDeviceMesh:
        shape = {"data": 1, "model": 1}
        devices = np.empty((1, 1), object)

    act = SH.activation_spec(OneDeviceMesh(), batch=32, d_model=512)
    assert act == P(None, None, None), act
    assert SH.norm_axes(("pod", "data"), OneDeviceMesh()) is None
    assert SH.norm_axes((), None) is None
    assert SH.norm_axes("data", None) == ("data",)


def test_specs_on_full_mesh_unchanged():
    """The fix must not perturb specs on a real pod/data/model mesh."""

    class FullMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        devices = np.empty((2, 16, 16), object)

    act = SH.activation_spec(FullMesh(), batch=64, d_model=4096)
    assert act == P(("pod", "data"), None, "model"), act


def test_make_host_mesh_override_and_submesh():
    n = len(jax.devices())
    default = make_host_mesh()
    assert dict(default.shape) == {"data": n, "model": 1}
    # explicit full shape
    full = make_host_mesh(shape=(n, 1))
    assert dict(full.shape) == {"data": n, "model": 1}
    # submesh over the first device (always possible)
    sub = make_host_mesh(shape=(1, 1))
    assert dict(sub.shape) == {"data": 1, "model": 1}
    assert sub.devices.flatten()[0] == jax.devices()[0]
    # custom axes ride along
    named = make_host_mesh(shape=(1,), axes=("tasks",))
    assert dict(named.shape) == {"tasks": 1}


def test_make_host_mesh_rejects_oversized_shape():
    n = len(jax.devices())
    with pytest.raises(ValueError) as e:
        make_host_mesh(shape=(n + 1, 2))
    # the error names both the requested and the available device count
    assert str(2 * (n + 1)) in str(e.value) and str(n) in str(e.value)
    with pytest.raises(ValueError):
        make_host_mesh(shape=(1, 1), axes=("data",))   # len mismatch
    with pytest.raises(AssertionError):
        make_host_mesh(axes=("data", "model"))         # axes without shape


def _req(rid, model_name="m", seed=None):
    return DSERequest(rid=rid, model_name=model_name,
                      net_idx=np.zeros(3, np.int64),
                      lat_obj=1.0, pow_obj=1.0,
                      seed=rid if seed is None else seed)


def test_batcher_prunes_drained_queues():
    """Regression: `_queues` grew one dead entry per retired model name
    forever (`models_with_work` scanned them on every dispatch)."""
    mb = MicroBatcher(max_batch=64)
    for m in range(20):
        mb.admit(_req(m, model_name=f"model-{m}"))
    for _ in range(20):
        assert mb.next_batch() is not None
    assert mb.next_batch() is None
    assert len(mb._queues) == 0          # drained queues pruned
    assert mb.models_with_work() == []
    # targeted pops prune too
    mb.admit(_req(99, model_name="m"))
    assert mb.next_batch("m") is not None
    assert len(mb._queues) == 0


def test_batcher_targeted_pop_keeps_round_robin_order():
    """Regression: a targeted ``next_batch(model_name=...)`` rotated the
    round-robin order, costing the front model its turn."""
    mb = MicroBatcher(max_batch=1, pad_pow2=False)
    for rid, m in [(0, "a"), (1, "a"), (2, "b"), (3, "b")]:
        mb.admit(_req(rid, model_name=m))
    # targeted pop of the front model must NOT rotate it to the back
    assert mb.next_batch("a").requests[0].rid == 0
    assert mb.models_with_work() == ["a", "b"]
    # round-robin pop serves 'a' (still front), THEN rotates it back
    assert mb.next_batch().requests[0].rid == 1
    assert mb.models_with_work() == ["b"]
    assert mb.next_batch().requests[0].rid == 2


def test_batcher_pads_to_shard_multiple():
    # 5 requests on 4 shards: ceil(5/4)=2 rows/shard, pow2(2)=2 -> 8 rows
    mb = MicroBatcher(max_batch=64, n_shards=4)
    for rid in range(5):
        mb.admit(_req(rid))
    b = mb.next_batch()
    assert (b.n_real, b.padded_size) == (5, 8)
    np.testing.assert_array_equal(b.seeds, [0, 1, 2, 3, 4, 4, 4, 4])
    assert len(b.tasks) == 8
    # without pow2 bucketing the target is the bare shard multiple
    mb = MicroBatcher(max_batch=64, pad_pow2=False, n_shards=4)
    for rid in range(5):
        mb.admit(_req(rid))
    assert mb.next_batch().padded_size == 8
    mb = MicroBatcher(max_batch=64, pad_pow2=False, n_shards=4)
    for rid in range(3):
        mb.admit(_req(rid))
    assert mb.next_batch().padded_size == 4
    # n_shards=1 reproduces the pre-mesh sizing exactly
    mb = MicroBatcher(max_batch=64, n_shards=1)
    for rid in range(5):
        mb.admit(_req(rid))
    assert mb.next_batch().padded_size == 8
    mb = MicroBatcher(max_batch=64, pad_pow2=False, n_shards=1)
    for rid in range(5):
        mb.admit(_req(rid))
    assert mb.next_batch().padded_size == 5


def test_batcher_follows_active_task_mesh():
    """n_shards=None reads the active mesh at formation time."""

    class FakeMesh:
        shape = {"data": 4, "model": 1}
        devices = np.empty((4, 1), object)

    mb = MicroBatcher(max_batch=64)
    for rid in range(3):
        mb.admit(_req(rid))
    with shard.task_mesh(FakeMesh()):
        assert mb.next_batch().padded_size == 4
    for rid in range(3):
        mb.admit(_req(rid + 10))
    assert mb.next_batch().padded_size == 4  # no mesh: plain pow2


def test_pad_tasks_roundtrip():
    class FakeMesh:
        shape = {"data": 4, "model": 1}
        devices = np.empty((4, 1), object)

    tasks = DSETask(net_idx=np.arange(12).reshape(6, 2),
                    lat_obj=np.arange(6.0), pow_obj=np.arange(6.0) + 10)
    seeds = np.arange(6, dtype=np.int64) + 100
    t_p, s_p, n = shard.pad_tasks(tasks, seeds, mesh=FakeMesh())
    assert n == 6 and len(t_p) == 8
    np.testing.assert_array_equal(t_p.net_idx[:6], tasks.net_idx)
    np.testing.assert_array_equal(t_p.net_idx[6:], tasks.net_idx[[5, 5]])
    np.testing.assert_array_equal(s_p, list(range(100, 106)) + [105, 105])
    # no mesh: plain pow2 bucket (same rule as the micro-batcher), so
    # direct explore_batch calls share one jit cache entry per bucket
    t_id, s_id, n_id = shard.pad_tasks(tasks, seeds, mesh=None)
    assert n_id == 6 and len(t_id) == 8
    np.testing.assert_array_equal(t_id.net_idx[:6], tasks.net_idx)
    np.testing.assert_array_equal(t_id.net_idx[6:], tasks.net_idx[[5, 5]])
    # an aligned batch is untouched
    t8, s8, n8 = shard.pad_tasks(t_id, s_id, mesh=None)
    assert t8 is t_id and n8 == 8


# ---------------------------------------------------------------------------
# sharded == single-device parity (multi-device only)
# ---------------------------------------------------------------------------
@multidevice
@pytest.mark.parametrize("n_tasks", [8, 6])   # aligned and ragged on 4
def test_explore_batch_parity_sharded(model, mesh, tiny_gan_cfg,
                                      small_dataset, n_tasks):
    eng = _attached(model, tiny_gan_cfg, small_dataset)
    tasks = generate_tasks(model, n_tasks, seed=2)
    base = eng.explore_batch(tasks, seed=7)
    with shard.task_mesh(mesh):
        sharded = eng.explore_batch(tasks, seed=7)
    _assert_results_equal(f"gandse[{n_tasks}]", base, sharded)


@multidevice
def test_select_batch_parity_sharded(model, mesh, tiny_gan_cfg,
                                     small_dataset):
    from repro.core.selector import select_batch
    eng = _attached(model, tiny_gan_cfg, small_dataset)
    tasks = generate_tasks(model, 8, seed=4)
    cand, valid, counts = eng._explorer.candidates_batch(
        tasks.net_idx, tasks.lat_obj, tasks.pow_obj, seed=11)
    base = select_batch(model, tasks.net_idx, cand, valid, counts,
                        tasks.lat_obj, tasks.pow_obj)
    with shard.task_mesh(mesh):
        sharded = select_batch(model, tasks.net_idx, cand, valid, counts,
                               tasks.lat_obj, tasks.pow_obj)
    for i, (sa, sb) in enumerate(zip(base, sharded)):
        if sa.cfg_idx is not None:
            np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx,
                                          err_msg=f"select[{i}]")
        assert sa.latency == sb.latency and sa.satisfied == sb.satisfied, i


@multidevice
def test_baseline_parity_sharded(model, mesh, small_dataset):
    from repro.baselines.drl import PolicyGradientDRL
    from repro.baselines.mlp import LargeMLP
    from repro.baselines.sa import SimulatedAnnealing

    ds = small_dataset(model, n=256)
    tasks = generate_tasks(model, 6, seed=3)   # ragged on 4 shards
    sa = SimulatedAnnealing(model, cooling=0.6)   # short anneal
    drl = PolicyGradientDRL(model, hidden_layers=2, neurons=16,
                            rollout_len=4).attach(ds, None)
    drl.params = drl.init_params(0)
    lm = LargeMLP(model, hidden_layers=2, neurons=32).attach(ds, None)
    lm.params = lm.init_params(0)
    for eng in (sa, drl, lm):
        base = eng.explore_tasks(tasks, seed=5)
        with shard.task_mesh(mesh):
            sharded = eng.explore_tasks(tasks, seed=5)
        _assert_results_equal(eng.method_name, base, sharded)


@multidevice
def test_train_parity_sharded(model, mesh, tiny_gan_cfg, small_dataset):
    """Data-parallel Algorithm 1 matches single-device up to float
    reduction order (losses are batch means, GSPMD all-reduces grads)."""
    from repro.core.train import train_gan
    cfg = tiny_gan_cfg(model, batch_size=32)
    ds = small_dataset(model, n=128)
    base = train_gan(model, ds, cfg, iters=2, seed=0)
    with shard.task_mesh(mesh):
        sharded = train_gan(model, ds, cfg, iters=2, seed=0)
    for which, pa, pb in (("g", base.g_params, sharded.g_params),
                          ("d", base.d_params, sharded.d_params)):
        flat_a = jax.tree.leaves(pa)
        flat_b = jax.tree.leaves(pb)
        for la, lb in zip(flat_a, flat_b):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=2e-4, atol=1e-6,
                                       err_msg=which)
    # loss history agrees too
    for ha, hb in zip(base.history, sharded.history):
        assert abs(ha["loss_g"] - hb["loss_g"]) < 1e-3, (ha, hb)


@multidevice
def test_train_falls_back_when_batch_does_not_divide(model, mesh,
                                                     tiny_gan_cfg,
                                                     small_dataset):
    from repro.core.train import train_gan
    cfg = tiny_gan_cfg(model, batch_size=30)   # 30 % 4 != 0
    ds = small_dataset(model, n=128)
    base = train_gan(model, ds, cfg, iters=1, seed=0)
    with shard.task_mesh(mesh):
        sharded = train_gan(model, ds, cfg, iters=1, seed=0)
    la, lb = jax.tree.leaves(base.g_params), jax.tree.leaves(sharded.g_params)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multidevice
def test_server_end_to_end_under_mesh(model, mesh, tiny_gan_cfg,
                                      small_dataset):
    """Single submissions through the serving stack under an active mesh:
    responses identical to the no-mesh server, batches sized to the shard
    multiple."""
    from repro.serve import DSEServer, ServeConfig

    tasks = generate_tasks(model, 5, seed=6)

    def run(active_mesh):
        srv = DSEServer(ServeConfig(max_batch=64, cache_capacity=0))
        srv.register(_attached(model, tiny_gan_cfg, small_dataset))
        with shard.task_mesh(active_mesh):
            rids = [srv.submit(model.name, tasks.net_idx[i],
                               tasks.lat_obj[i], tasks.pow_obj[i],
                               seed=100 + i)
                    for i in range(5)]
            srv.drain()
        return srv, [srv.response(r) for r in rids]

    srv0, base = run(None)
    srv1, sharded = run(mesh)
    for i, (ra, rb) in enumerate(zip(base, sharded)):
        np.testing.assert_array_equal(ra.result.selection.cfg_idx,
                                      rb.result.selection.cfg_idx,
                                      err_msg=f"serve[{i}]")
        assert ra.result.selection.latency == rb.result.selection.latency
    # 5 requests -> one 8-row batch under the 4-way mesh (3 padded rows)
    assert srv1.stats["padded_rows"] == 3
    assert srv1.summary()["sharding"]["n_shards"] == 1  # mesh exited
    with shard.task_mesh(mesh):
        assert srv1.summary()["sharding"]["n_shards"] == shard.n_task_shards(mesh)
