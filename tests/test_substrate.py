"""Substrate tests: MoE/SSM/xLSTM numerics, optimizer, compression, data,
checkpoints, HLO parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.nn import moe as M
from repro.nn import ssm as S
from repro.nn import xlstm as X


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_matches_dense_oracle_when_capacity_sufficient(rng):
    x = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    p = M.moe_init(jax.random.PRNGKey(0), 4, 16, 32)
    logits = x @ p["router"]
    idx, w = M.route_topk(logits, 2)
    got = M.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    want = ref.moe_dispatch_ffn(x, p["w_gate"], p["w_up"], p["w_down"], idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_partial_not_nan(rng):
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    p = M.moe_init(jax.random.PRNGKey(1), 4, 16, 32)
    y = M.moe_apply(p, x, top_k=2, capacity_factor=0.25)   # heavy dropping
    assert not np.isnan(np.asarray(y)).any()


def test_moe_aux_loss_bounds(rng):
    x = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    p = M.moe_init(jax.random.PRNGKey(2), 8, 16, 32)
    _, aux = M.moe_apply(p, x, top_k=2, aux_loss=True)
    assert float(aux) >= 1.0 - 1e-3    # >= 1 by Cauchy-Schwarz, =1 balanced


# ---------------------------------------------------------------------------
# SSM / xLSTM streaming consistency
# ---------------------------------------------------------------------------
@pytest.mark.slow   # 24-token eager decode loop; chunked variants cover tier-1
def test_ssm_decode_matches_full_scan(rng):
    p = S.ssm_init(jax.random.PRNGKey(0), 16, d_state=8)
    x = jnp.asarray(rng.normal(size=(2, 24, 16)), jnp.float32)
    full = S.ssm_apply(p, x)
    state = S.ssm_decode_init(p, 2)
    outs = []
    for t in range(24):
        y, state = S.ssm_decode_step(p, x[:, t:t + 1], state)
        outs.append(y)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunked_equals_full(rng):
    p = X.mlstm_init(jax.random.PRNGKey(0), 32, 4)
    x = jnp.asarray(rng.normal(size=(2, 32, 32)), jnp.float32)
    full, _ = X.mlstm_apply(p, x, 4)
    y1, st = X.mlstm_apply(p, x[:, :16], 4)
    y2, _ = X.mlstm_apply(p, x[:, 16:], 4, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=2e-3, atol=2e-3)


def test_slstm_chunked_equals_full(rng):
    p = X.slstm_init(jax.random.PRNGKey(0), 32, 4)
    x = jnp.asarray(rng.normal(size=(2, 20, 32)), jnp.float32)
    full, _ = X.slstm_apply(p, x, 4)
    y1, st = X.slstm_apply(p, x[:, :10], 4)
    y2, _ = X.slstm_apply(p, x[:, 10:], 4, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=2e-3, atol=2e-3)


def test_mlstm_forget_gate_decays_memory():
    """With strongly negative forget bias the state forgets quickly."""
    p = X.mlstm_init(jax.random.PRNGKey(0), 16, 2)
    p = dict(p, b_f=jnp.full((2,), -20.0))
    x = jnp.ones((1, 8, 16))
    y, (c, n, m) = X.mlstm_apply(p, x, 2)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    from repro.optim import adamw, apply_updates
    opt = adamw(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_grad_compression_error_feedback_unbiased(rng):
    """Error feedback: the accumulated quantization error stays bounded and
    the mean dequantized gradient tracks the true mean."""
    from repro.optim.compress import GradCompressor
    comp = GradCompressor()
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    res = comp.init(g_true)
    acc = np.zeros(256)
    for _ in range(50):
        gq, res = comp(g_true, res)
        acc += np.asarray(gq["w"])
    np.testing.assert_allclose(acc / 50, np.asarray(g_true["w"]),
                               rtol=0, atol=2e-2)


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm
    from repro.optim.adamw import global_norm
    g = {"a": jnp.full((10,), 100.0)}
    gc = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(gc)) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_shards_partition_global_batch():
    from repro.data.synthetic import DataConfig, SyntheticStream
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8)
    s = SyntheticStream(cfg)
    full_t, full_l = s.batch(7)
    parts = [s.batch(7, i, 4) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate([p[0] for p in parts]), full_t)
    np.testing.assert_array_equal(np.concatenate([p[1] for p in parts]), full_l)


def test_data_deterministic_and_step_dependent():
    from repro.data.synthetic import DataConfig, SyntheticStream
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4)
    a = SyntheticStream(cfg).batch(3)[0]
    b = SyntheticStream(cfg).batch(3)[0]
    c = SyntheticStream(cfg).batch(4)[0]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 128


def test_data_labels_are_shifted_tokens():
    from repro.data.synthetic import DataConfig, SyntheticStream
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=2)
    t, l = SyntheticStream(cfg).batch(0)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    ck = CheckpointManager(str(tmp_path), keep_last_n=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4)]}
    ck.save(10, tree, extra={"foo": 1})
    out = ck.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert ck.restore_extra(10)["foo"] == 1


def test_checkpoint_keep_last_n_and_latest(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    ck = CheckpointManager(str(tmp_path), keep_last_n=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_partial_write_is_not_resumable(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    ck = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.zeros(2)}
    ck.save(5, tree)
    # simulate a torn write: step dir without manifest
    os.makedirs(tmp_path / "step_000000009")
    assert ck.latest_step() == 5


def test_checkpoint_restore_with_struct_likes(tmp_path):
    """restore() accepts ShapeDtypeStruct likes (donated-buffer safety)."""
    from repro.checkpoint.manager import CheckpointManager
    ck = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    ck.save(1, tree)
    like = {"a": jax.ShapeDtypeStruct((4,), jnp.float32)}
    out = ck.restore(1, like)
    np.testing.assert_array_equal(out["a"], np.arange(4, dtype=np.float32))


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
def test_collective_bytes_parser():
    from repro.utils.hlo import collective_bytes
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(bf16[1,128] %x), dim=0
  %ar = f32[256]{0} all-reduce(f32[256] %y), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(f32[256] %z), dim=0
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8] %a, f32[8,8] %b)
  %cp = u8[100]{0} collective-permute(u8[100] %w)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 4 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 64 * 4
    assert out["all-to-all"] == 2 * 64 * 4
    assert out["collective-permute"] == 100
    assert out["n_ops"] == 5
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_bytes_real_lowering():
    """Parser agrees with a known tiny SPMD program: an all-reduce of a
    (8,) f32 under psum."""
    import jax
    from repro.launch.mesh import make_mesh
    from repro.utils.hlo import collective_bytes
    mesh = make_mesh((len(jax.devices()),), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P())), x.sum()

    xsh = NamedSharding(mesh, P("d"))
    with mesh:
        txt = jax.jit(lambda x: x.sum(), in_shardings=(xsh,),
                      out_shardings=NamedSharding(mesh, P())
                      ).lower(jax.ShapeDtypeStruct((8,), jnp.float32)
                              ).compile().as_text()
    out = collective_bytes(txt)
    assert out["n_ops"] >= 1 or len(jax.devices()) == 1


@pytest.mark.slow   # 128-token x 2 routes; the 32-token streaming test covers tier-1
def test_mlstm_chunkwise_matches_stepwise(rng):
    """The chunkwise-parallel mLSTM (§Perf xlstm hillclimb) is numerically
    identical to the stepwise reference, including carried state."""
    p = X.mlstm_init(jax.random.PRNGKey(3), 64, 4)
    x = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    y_step, st_step = X.mlstm_apply(p, x, 4, chunkwise=False)
    y_chunk, st_chunk = X.mlstm_apply(p, x, 4, chunkwise=True, chunk=32)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    for a, b in zip(st_chunk, st_step):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    # streaming across chunkwise calls
    y1, st1 = X.mlstm_apply(p, x[:, :64], 4, chunkwise=True, chunk=32)
    y2, _ = X.mlstm_apply(p, x[:, 64:], 4, chunkwise=True, chunk=32,
                          state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_step),
        rtol=2e-3, atol=2e-3)
