"""Slot-reuse regression for the continuous-batching LM engine.

`launch/serve.py` used to carry a no-op "reset" (`st.at[...].set(st) if
False else st`) when admitting a request into a freed batch slot, so the
new stream attended to the previous occupant's stale KV entries (they sit
*below* the shared `len` watermark, which the causal mask does not hide).
The fix masks each lane's cache below its admission clock
(`decode_step(start=...)`) and re-initializes per-lane recurrent state, so
a reused slot must decode exactly what a fresh engine would.

gemma3-1b-reduced covers both ring (windowed) and global attention KV;
xlstm-reduced covers the recurrent (mlstm/slstm) lane reset.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import Engine, Request
from repro.models import base as MB


@pytest.fixture(scope="module")
def engines():
    out = {}
    for arch in ("gemma3-1b", "xlstm-1.3b"):
        m = configs.get_reduced(arch)
        params = MB.init_params(jax.random.PRNGKey(0), m)
        out[arch] = (m, params)
    return out


def _serve(m, params, prompts, slots, cache_len=64, max_new=6):
    eng = Engine(m, params, slots, cache_len)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), max_new=max_new))
    eng.run(max_iters=512)
    assert len(eng.finished) == len(prompts)
    return {r.rid: r.out for r in eng.finished}


@pytest.mark.parametrize("arch", ["gemma3-1b", "xlstm-1.3b"])
def test_reused_slot_matches_fresh_engine(arch, engines):
    """Back-to-back requests through ONE slot: the second request decodes
    on top of the first one's leftover state and must still match a fresh
    -engine run of the same prompt."""
    m, params = engines[arch]
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, m.vocab, size=12).tolist()
    p2 = rng.integers(0, m.vocab, size=9).tolist()
    reused = _serve(m, params, [p1, p2], slots=1)
    fresh = _serve(m, params, [p2], slots=1)
    assert reused[1] == fresh[0], "reused slot leaked the previous request"
    # sanity: the first request matches its own fresh run too
    assert reused[0] == _serve(m, params, [p1], slots=1)[0]


def test_kv_capacity_exhaustion_raises(engines):
    """Global-attention KV caches are append-only across the engine's
    lifetime: once the clock reaches cache_len, decode would silently
    clamp writes onto the last slot — the engine must fail loudly
    instead (regression for the silent-garbage failure mode)."""
    m, params = engines["gemma3-1b"]
    rng = np.random.default_rng(2)
    # cache_len must cover the 32-wide attention window (ring span), but 36
    # total engine steps are fewer than the two requests need (12 + 32)
    eng = Engine(m, params, 1, cache_len=36)
    eng.submit(Request(rid=0, prompt=rng.integers(0, m.vocab, 8).tolist(),
                       max_new=4))
    eng.submit(Request(rid=1, prompt=rng.integers(0, m.vocab, 8).tolist(),
                       max_new=24))
    with pytest.raises(RuntimeError, match="KV capacity"):
        eng.run(max_iters=64)


def test_reused_slot_matches_fresh_engine_interleaved(engines):
    """Slot reuse while ANOTHER stream is mid-flight: request 3 is admitted
    into whichever of the two slots frees first (its stream start lands
    mid-clock, exercising the per-lane mask against live neighbours)."""
    m, params = engines["gemma3-1b"]
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, m.vocab, size=n).tolist() for n in (10, 14, 8)]
    served = _serve(m, params, prompts, slots=2)
    for rid, p in enumerate(prompts):
        assert served[rid] == _serve(m, params, [p], slots=1)[0], rid
