"""Loop-aware HLO cost model: the motivating XLA behaviour + correctness
on a known scanned SPMD program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils.hlo_cost import analyze

M, K, N, TRIPS = 128, 256, 64, 8


def _compiled_text():
    def f(w, x):
        def body(c, _):
            return jnp.maximum(w @ c, 0), None
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y.sum()

    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("d",))
    with mesh:
        c = jax.jit(
            f,
            in_shardings=(NamedSharding(mesh, P(None, "d")),
                          NamedSharding(mesh, P())),
            out_shardings=NamedSharding(mesh, P()),
        ).lower(jax.ShapeDtypeStruct((K, K), jnp.float32),
                jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    return c


def test_xla_cost_analysis_counts_loop_body_once():
    """The motivating defect: XLA reports ~1 iteration of the scan."""
    c = _compiled_text()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    n_dev = len(jax.devices())
    one_iter = 2 * K * K * (N // n_dev if N % n_dev == 0 else N)
    assert float(ca.get("flops", 0)) < 2 * one_iter  # ~1 iter, not TRIPS


def test_loop_aware_flops_multiply_trip_count():
    c = _compiled_text()
    t = analyze(c.as_text())
    n_dev = len(jax.devices())
    local_n = N // n_dev if N % n_dev == 0 else N
    expect = TRIPS * 2 * K * K * local_n
    assert abs(t["flops"] - expect) / expect < 0.05


def test_loop_aware_collectives_multiply_trip_count():
    c = _compiled_text()
    t = analyze(c.as_text())
    if len(jax.devices()) == 1:
        pytest.skip("no collectives on 1 device")
    # the weight all-gather runs once per iteration
    assert t["coll_all-gather"] >= TRIPS * K * K * 4


def test_unlooped_program_matches_xla():
    """Without loops the parser agrees with cost_analysis on dot flops."""
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    t = analyze(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert abs(t["flops"] - float(ca["flops"])) <= 0.05 * float(ca["flops"])
