"""The concurrent serving front end (`repro.serve.frontend.ServeFrontend`).

Contract under test: batching, threading, admission control, deadlines,
retries, and fault injection are all invisible to correctness — every
non-rejected response is Selection-identical to a standalone
`explore_tasks` call — and every submitted request terminates in exactly
one of DONE / FAILED / REJECTED, under healthy engines, slow engines,
injected device-route faults (where the degraded host fallback must
activate and then recover), and shutdown.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.serve import (DSEServer, FaultPlan, FaultyEngine, FrontendConfig,
                         ServeConfig, ServeFrontend)

MODEL = DnnWeaverModel()


@pytest.fixture(scope="module")
def engine(tiny_gan_cfg, small_dataset):
    """Random-init generator: serving correctness does not depend on
    training quality (same rationale as test_serve)."""
    cfg = tiny_gan_cfg(MODEL)
    g = GANDSE(MODEL, cfg,
               ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(MODEL, n=256)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, MODEL.space))
    return g


class SlowEngine:
    """Transparent wrapper that stalls every dispatch (host-side sleep) —
    builds queue pressure for the admission/deadline tests."""

    def __init__(self, inner, delay_s):
        self._inner, self.delay_s = inner, delay_s
        self.model = inner.model
        self.method_name = inner.method_name

    def explore_tasks(self, tasks, seed=0, batched=None):
        time.sleep(self.delay_s)
        return self._inner.explore_tasks(tasks, seed=seed, batched=batched)


def _assert_selection_equal(tag, i, sa, sb):
    assert sa.n_candidates == sb.n_candidates, (tag, i)
    assert (sa.cfg_idx is None) == (sb.cfg_idx is None), (tag, i)
    if sa.cfg_idx is not None:
        np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx,
                                      err_msg=f"{tag}[{i}]")
    assert sa.latency == sb.latency and sa.power == sb.power, (tag, i)
    assert sa.satisfied == sb.satisfied, (tag, i)


def _submit_tasks(fe, tasks, n, seed0=7, timeout_s=None):
    futs = {}
    for i in range(n):
        fut = fe.submit(MODEL.name, tasks.net_idx[i], tasks.lat_obj[i],
                        tasks.pow_obj[i], seed=seed0 + i,
                        timeout_s=timeout_s)
        futs[fut.rid] = (i, fut)
    return futs


def test_frontend_parity_with_direct_batch(engine):
    """Threaded submit/form/dispatch pipeline == one direct explore_tasks
    call, row by row; every future resolves DONE."""
    tasks = generate_tasks(MODEL, 10, seed=2)
    direct = engine.explore_tasks(tasks, seed=7)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(engine)
    with ServeFrontend(srv) as fe:
        futs = _submit_tasks(fe, tasks, 10)
        for rid, (i, fut) in futs.items():
            resp = fut.result(timeout=60)
            assert resp.ok and resp.source in ("dispatch", "cache",
                                               "coalesced")
            _assert_selection_equal("parity", i, resp.result.selection,
                                    direct[i].selection)
    assert srv.batcher.pending() == 0


def test_frontend_cache_and_coalesce(engine):
    """Identical submissions dispatch once: the duplicate rides the queued
    request (coalesced) or hits the LRU (cache) depending on timing —
    either way the Selections agree and no extra row is dispatched."""
    tasks = generate_tasks(MODEL, 3, seed=2)
    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(engine)
    with ServeFrontend(srv) as fe:
        first = _submit_tasks(fe, tasks, 3)
        dup = [fe.submit(MODEL.name, tasks.net_idx[i], tasks.lat_obj[i],
                         tasks.pow_obj[i], seed=7 + i) for i in range(3)]
        by_row = {i: fut.result(60) for _, (i, fut) in first.items()}
        for i, fut in enumerate(dup):
            resp = fut.result(timeout=60)
            assert resp.source in ("cache", "coalesced"), resp.source
            _assert_selection_equal("dup", i, resp.result.selection,
                                    by_row[i].result.selection)
    assert srv.stats["dispatched_rows"] == 3        # duplicates rode along


def test_frontend_admission_reject_sheds_load(engine):
    """Queue-bound admission with the reject policy: a burst beyond
    max_queue is shed at the door with retry-after hints; everything else
    is served; nothing wedges."""
    srv = DSEServer(ServeConfig(max_batch=1, max_queue=2,
                                cache_capacity=0, retry_jitter=0.0))
    srv.register(SlowEngine(engine, delay_s=0.05))
    tasks = generate_tasks(MODEL, 12, seed=2)
    with ServeFrontend(srv, FrontendConfig(admission="reject")) as fe:
        futs = _submit_tasks(fe, tasks, 12)
        resps = [fut.result(timeout=60) for _, fut in futs.values()]
    rejected = [r for r in resps if r.rejected]
    served = [r for r in resps if r.ok]
    assert len(rejected) + len(served) == 12            # all terminated
    assert rejected, "a 12-deep burst into a 2-deep queue must shed"
    assert all(r.retry_after and r.retry_after > 0 for r in rejected)
    assert all("queue full" in r.error for r in rejected)
    assert srv.stats["rejected_queue"] == len(rejected)


def test_frontend_admission_block_backpressures(engine):
    """The block policy serves everything: a full queue stalls the
    submitter until space frees instead of shedding."""
    srv = DSEServer(ServeConfig(max_batch=2, max_queue=2, cache_capacity=0))
    srv.register(SlowEngine(engine, delay_s=0.01))
    tasks = generate_tasks(MODEL, 8, seed=2)
    with ServeFrontend(srv, FrontendConfig(admission="block")) as fe:
        futs = _submit_tasks(fe, tasks, 8)
        resps = [fut.result(timeout=60) for _, fut in futs.values()]
    assert all(r.ok for r in resps)
    assert srv.stats["rejected"] == 0


def test_frontend_deadline_sheds_expired(engine):
    """Requests whose deadline passes while queued behind a slow dispatch
    are shed before dispatch (REJECTED, deadline error).  Shedding is
    best-effort by contract: a request *already formed* into the prepared
    -batch window when its deadline passes is served late instead — at
    most max_prepared+1 batches can be in flight past the former, so the
    stragglers behind them must all shed."""
    srv = DSEServer(ServeConfig(max_batch=1, cache_capacity=0))
    srv.register(SlowEngine(engine, delay_s=0.3))
    tasks = generate_tasks(MODEL, 8, seed=2)
    with ServeFrontend(srv, FrontendConfig(max_prepared=1)) as fe:
        # rid 0 occupies the dispatcher for ~0.3 s; the rest carry 50 ms
        # deadlines and expire queued behind it (except the <=2 the former
        # managed to pre-form before the deadline hit)
        lead = fe.submit(MODEL.name, tasks.net_idx[0], tasks.lat_obj[0],
                         tasks.pow_obj[0], seed=7)
        time.sleep(0.05)            # let the lead batch reach the engine
        late = [fe.submit(MODEL.name, tasks.net_idx[i], tasks.lat_obj[i],
                          tasks.pow_obj[i], seed=7 + i, timeout_s=0.05)
                for i in range(1, 8)]
        assert lead.result(timeout=60).ok
        resps = [fut.result(timeout=60) for fut in late]
    rejected = [r for r in resps if r.rejected]
    served = [r for r in resps if r.ok]
    assert len(rejected) + len(served) == 7          # all terminated
    # one batch in the prepared buffer + one formed-and-blocked at the put:
    # everything behind them expires in the queue and must shed
    assert len(served) <= 2 and len(rejected) >= 5
    assert all("deadline" in r.error for r in rejected)
    assert srv.stats["rejected_deadline"] == len(rejected)


def test_frontend_degraded_fallback_activates_and_recovers(engine):
    """A burst of device-route faults flips the model onto the sequential
    host-oracle route (responses flagged degraded, Selections unchanged);
    once the fault window passes, a recovery probe restores the device
    route.  No request is lost or FAILED."""
    plan = FaultPlan(burst_start=0, burst_len=3, device_route_only=True)
    faulty = FaultyEngine(engine, plan)
    srv = DSEServer(ServeConfig(
        max_batch=2, cache_capacity=0, max_dispatch_attempts=10,
        retry_backoff_base=0.005, retry_jitter=0.0,
        degrade_after=2, degrade_probe_after=1))
    srv.register(faulty)
    tasks = generate_tasks(MODEL, 10, seed=2)
    direct = engine.explore_tasks(tasks, seed=7)
    with ServeFrontend(srv) as fe:
        futs = _submit_tasks(fe, tasks, 10)
        resps = {}
        for rid, (i, fut) in futs.items():
            resps[i] = fut.result(timeout=120)
    assert all(r.ok for r in resps.values()), \
        {i: (r.source, r.error) for i, r in resps.items() if not r.ok}
    for i, r in resps.items():
        _assert_selection_equal("faulty", i, r.result.selection,
                                direct[i].selection)
    assert faulty.injected_errors == 3
    assert srv.stats["degraded_entered"] == 1
    assert srv.stats["degraded_batches"] >= 1
    assert srv.stats["degraded_recovered"] == 1
    assert not srv.summary()["degraded"]              # healthy again
    assert any(r.degraded for r in resps.values())
    assert srv.stats["failed"] == 0


def test_frontend_stop_without_drain_rejects_queued(engine):
    """stop(drain=False) terminates every outstanding future: queued
    requests get REJECTED shutdown responses instead of hanging."""
    srv = DSEServer(ServeConfig(max_batch=1, cache_capacity=0))
    srv.register(SlowEngine(engine, delay_s=0.2))
    tasks = generate_tasks(MODEL, 6, seed=2)
    fe = ServeFrontend(srv).start()
    futs = _submit_tasks(fe, tasks, 6)
    time.sleep(0.05)                 # let the pipeline pick up some work
    fe.stop(drain=False)
    states = [fut.result(timeout=60) for _, fut in futs.values()]
    assert all(r.ok or r.rejected for r in states)
    assert any(r.rejected and "shutting down" in r.error for r in states)
    assert srv.batcher.pending() == 0


def test_frontend_metrics_snapshot(engine):
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(engine)
    tasks = generate_tasks(MODEL, 4, seed=2)
    with ServeFrontend(srv) as fe:
        futs = _submit_tasks(fe, tasks, 4)
        for _, fut in futs.values():
            fut.result(timeout=60)
        m = fe.metrics()
    lat = m["frontend"]["latency"]
    assert lat["n"] == 4 and lat["p99_ms"] >= lat["p50_ms"] > 0
    assert m["frontend"]["inflight"] == 0
    assert m["dispatch_attempts"] >= m["batches"] >= 1


def test_frontend_swap_during_dispatch_parity(tiny_gan_cfg, small_dataset):
    """Hot-swap parity on a live front end: before the swap, responses
    match a params-A reference; after `ServeFrontend.swap` to params B,
    fresh requests match a params-B reference; and *identical re-asks* of
    pre-swap requests are served by dispatch under the NEW params — the
    swap's invalidation (plus the params-generation stamp) guarantees no
    params-A Selection survives in the cache."""
    cfg = tiny_gan_cfg(MODEL)
    ds = small_dataset(MODEL, n=256)
    params_a = G.init_generator(jax.random.PRNGKey(3), cfg, MODEL.space)
    params_b = G.init_generator(jax.random.PRNGKey(4), cfg, MODEL.space)
    ecfg = ExplorerConfig(prob_threshold=0.1, max_candidates=128)
    serving = GANDSE(MODEL, cfg, ecfg)
    serving.attach(ds, params_a)
    ref_a = GANDSE(MODEL, cfg, ecfg)          # immutable references
    ref_a.attach(ds, params_a)
    ref_b = GANDSE(MODEL, cfg, ecfg)
    ref_b.attach(ds, params_b)

    tasks = generate_tasks(MODEL, 6, seed=2)
    direct_a = ref_a.explore_tasks(tasks, seed=7)
    direct_b = ref_b.explore_tasks(tasks, seed=7)
    direct_b2 = ref_b.explore_tasks(tasks, seed=107)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(serving)
    with ServeFrontend(srv) as fe:
        wave_a = _submit_tasks(fe, tasks, 6, seed0=7)
        for rid, (i, fut) in wave_a.items():
            _assert_selection_equal("pre-swap", i,
                                    fut.result(60).result.selection,
                                    direct_a[i].selection)
        gen0 = srv.params_generation(MODEL.name)
        fe.swap(MODEL.name, ds, params_b)
        assert srv.params_generation(MODEL.name) == gen0 + 1
        # fresh keys after the swap: the new params serve them
        wave_b = _submit_tasks(fe, tasks, 6, seed0=107)
        for rid, (i, fut) in wave_b.items():
            _assert_selection_equal("post-swap", i,
                                    fut.result(60).result.selection,
                                    direct_b2[i].selection)
        # identical re-asks of wave A: the invalidation dropped their
        # cached params-A results, so they re-dispatch under params B
        redo = _submit_tasks(fe, tasks, 6, seed0=7)
        for rid, (i, fut) in redo.items():
            resp = fut.result(60)
            assert resp.source in ("dispatch", "coalesced"), resp.source
            _assert_selection_equal("re-ask", i, resp.result.selection,
                                    direct_b[i].selection)
    assert srv.stats["swaps"] == 1


def test_frontend_concurrent_submitters(engine):
    """Many submitter threads at once: the one-lock admission path keeps
    rids unique and every future resolves with the right Selection."""
    tasks = generate_tasks(MODEL, 16, seed=2)
    direct = engine.explore_tasks(tasks, seed=7)
    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(engine)
    results = {}
    errors = []
    lock = threading.Lock()

    def submitter(rows):
        try:
            for i in rows:
                fut = fe.submit(MODEL.name, tasks.net_idx[i],
                                tasks.lat_obj[i], tasks.pow_obj[i],
                                seed=7 + i)
                resp = fut.result(timeout=120)
                with lock:
                    results[i] = resp
        except Exception as e:      # pragma: no cover - surfaced below
            errors.append(e)

    with ServeFrontend(srv) as fe:
        threads = [threading.Thread(target=submitter,
                                    args=(range(k, 16, 4),))
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
    assert not errors, errors
    assert len(results) == 16
    for i, resp in results.items():
        assert resp.ok
        _assert_selection_equal("mt", i, resp.result.selection,
                                direct[i].selection)
