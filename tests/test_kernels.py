"""Per-kernel validation: Pallas interpret mode vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels import fused_mlp as FM
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# fused dense
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (128, 256, 512),      # aligned
    (256, 1024, 768),     # multi-block K
    (100, 36, 50),        # odd (operands padded up to the block multiple)
    (97, 131, 53),        # prime dims (must NOT fall back to whole-dim blocks)
    (1, 8, 16),           # degenerate
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("relu", [True, False])
def test_fused_dense_matches_ref(m, k, n, dtype, relu, rng):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, dtype)
    b = jnp.asarray(rng.normal(size=(n,)), dtype)
    got = FM.fused_dense(x, w, b, relu=relu, interpret=True)
    want = ref.fused_dense_relu(x, w, b) if relu else ref.fused_dense(x, w, b)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_pick_is_vmem_bounded():
    """A prime/odd dim must never produce a block bigger than requested
    (the old _pick returned the whole dim, blowing the VMEM budget)."""
    for dim in (997, 1021, 2049, 100, 36, 7, 1):
        for block in (64, 128, 256, 512):
            assert FM._pick(block, dim) <= block, (block, dim)
    # aligned dims keep the requested block exactly
    assert FM._pick(512, 2048) == 512
    assert FM._pick(256, 1024) == 256


@pytest.mark.parametrize("m,k,n", [
    (64, 128, 96),        # aligned
    (97, 131, 53),        # prime (exercises the padded route end to end)
])
@pytest.mark.parametrize("relu", [True, False])
def test_fused_dense_grad_matches_ref(m, k, n, relu, rng):
    """jax.grad through the custom_vjp (Pallas backward kernels, interpret
    mode) == grad of the jnp reference, for dx, dw, AND db."""
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    ct = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)

    def loss(fn):
        return lambda x, w, b: jnp.sum(fn(x, w, b) * ct)

    got = jax.grad(loss(lambda x, w, b: FM.fused_dense(
        x, w, b, relu=relu, interpret=True)), argnums=(0, 1, 2))(x, w, b)
    ref_fn = ref.fused_dense_relu if relu else ref.fused_dense
    want = jax.grad(loss(ref_fn), argnums=(0, 1, 2))(x, w, b)
    for g, r, name in zip(got, want, ("dx", "dw", "db")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def _mlp_params(rng, dims):
    ws = tuple(jnp.asarray(rng.normal(size=d) * 0.1, jnp.float32) for d in dims)
    bs = tuple(jnp.asarray(rng.normal(size=(d[1],)), jnp.float32) for d in dims)
    return ws, bs


@pytest.mark.parametrize("m,dims", [
    (32, [(24, 64), (64, 64), (64, 48)]),    # aligned-ish widths
    (33, [(37, 61), (61, 61), (61, 29)]),    # prime everything
    (8, [(16, 32)]),                         # single (linear) layer
])
def test_fused_mlp_megakernel_matches_ref(m, dims, rng):
    """The layer-chained megakernel == the jnp chain (hidden ReLU, linear
    head), including awkward (padded) widths."""
    x = jnp.asarray(rng.normal(size=(m, dims[0][0])), jnp.float32)
    ws, bs = _mlp_params(rng, dims)
    got = FM.fused_mlp(x, ws, bs, interpret=True)
    want = ref.fused_mlp(x, ws, bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fused_mlp_megakernel_grad_matches_ref(rng):
    """grad through the megakernel VJP (fused_dense chain recompute) ==
    grad of the jnp chain — the final linear (relu=False) layer included."""
    dims = [(19, 40), (40, 40), (40, 23)]
    x = jnp.asarray(rng.normal(size=(17, 19)), jnp.float32)
    ws, bs = _mlp_params(rng, dims)
    ct = jnp.asarray(rng.normal(size=(17, 23)), jnp.float32)

    got = jax.grad(lambda x, ws, bs: jnp.sum(
        FM.fused_mlp(x, ws, bs, interpret=True) * ct),
        argnums=(0, 1, 2))(x, ws, bs)
    want = jax.grad(lambda x, ws, bs: jnp.sum(
        ref.fused_mlp(x, ws, bs) * ct), argnums=(0, 1, 2))(x, ws, bs)
    jax.tree.map(
        lambda g, r: np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=1e-4, atol=1e-4),
        got, want)


def test_fused_dense_block_shapes(rng):
    """Different BlockSpec tilings give identical results."""
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 256)) * 0.05, jnp.float32)
    b = jnp.zeros((256,), jnp.float32)
    ys = [FM.fused_dense(x, w, b, bm=bm, bk=bk, bn=bn, interpret=True)
          for bm, bk, bn in [(64, 128, 64), (256, 512, 256), (128, 256, 128)]]
    for y in ys[1:]:
        # different K-split accumulation orders: bitwise inequality expected
        np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 64), (False, None),
])
def test_flash_attention_matches_ref(h, hkv, causal, window, rng):
    b, s, d = 2, 256, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    got = FA.flash_attention(q, k, v, causal=causal, window=window,
                             bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol, rng):
    b, h, hkv, s, d = 1, 4, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    got = FA.flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_q_offset(rng):
    """Chunked prefill: attending with q_offset equals the full pass."""
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    full = FA.flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    part = FA.flash_attention(q[:, :, 64:], k, v, q_offset=64,
                              bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, :, 64:]),
                               rtol=2e-4, atol=2e-4)


def test_xla_fallback_matches_pallas(rng):
    """ops.py dispatching: XLA fallback == Pallas interpret numerics."""
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    a = ops.flash_attention(q, k, v, interpret=True)
    bb = ops.flash_attention(q, k, v, interpret=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=2e-4, atol=2e-4)


def test_blocked_xla_attention_matches_reference(rng):
    """nn/attention.py blocked online-softmax path vs unblocked reference."""
    from repro.nn import attention as A
    b, s, h, hkv, d = 2, 512, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    for window in (None, 128):
        got = A.flash_attention_xla(q, k, v, causal=True, window=window,
                                    q_block=128, kv_block=128)
        want = A.attention_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
