"""Per-kernel validation: Pallas interpret mode vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels import fused_mlp as FM
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# fused dense
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [
    (128, 256, 512),      # aligned
    (256, 1024, 768),     # multi-block K
    (100, 36, 50),        # odd (falls back to whole-dim blocks)
    (1, 8, 16),           # degenerate
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("relu", [True, False])
def test_fused_dense_matches_ref(m, k, n, dtype, relu, rng):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, dtype)
    b = jnp.asarray(rng.normal(size=(n,)), dtype)
    got = FM.fused_dense(x, w, b, relu=relu, interpret=True)
    want = ref.fused_dense_relu(x, w, b) if relu else ref.fused_dense(x, w, b)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fused_dense_block_shapes(rng):
    """Different BlockSpec tilings give identical results."""
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 256)) * 0.05, jnp.float32)
    b = jnp.zeros((256,), jnp.float32)
    ys = [FM.fused_dense(x, w, b, bm=bm, bk=bk, bn=bn, interpret=True)
          for bm, bk, bn in [(64, 128, 64), (256, 512, 256), (128, 256, 128)]]
    for y in ys[1:]:
        # different K-split accumulation orders: bitwise inequality expected
        np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 64), (False, None),
])
def test_flash_attention_matches_ref(h, hkv, causal, window, rng):
    b, s, d = 2, 256, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    got = FA.flash_attention(q, k, v, causal=causal, window=window,
                             bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol, rng):
    b, h, hkv, s, d = 1, 4, 2, 128, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    got = FA.flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_q_offset(rng):
    """Chunked prefill: attending with q_offset equals the full pass."""
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    full = FA.flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    part = FA.flash_attention(q[:, :, 64:], k, v, q_offset=64,
                              bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, :, 64:]),
                               rtol=2e-4, atol=2e-4)


def test_xla_fallback_matches_pallas(rng):
    """ops.py dispatching: XLA fallback == Pallas interpret numerics."""
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    a = ops.flash_attention(q, k, v, interpret=True)
    bb = ops.flash_attention(q, k, v, interpret=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=2e-4, atol=2e-4)


def test_blocked_xla_attention_matches_reference(rng):
    """nn/attention.py blocked online-softmax path vs unblocked reference."""
    from repro.nn import attention as A
    b, s, h, hkv, d = 2, 512, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    for window in (None, 128):
        got = A.flash_attention_xla(q, k, v, causal=True, window=window,
                                    q_block=128, kv_block=128)
        want = A.attention_reference(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
