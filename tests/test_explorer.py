"""Candidate enumeration (probability threshold) properties — §6.1."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.encoding import ConfigDim, ConfigSpace
from repro.core.explorer import enumerate_candidates


def _space(sizes):
    return ConfigSpace(dims=tuple(
        ConfigDim(f"d{i}", tuple(float(j) for j in range(n)))
        for i, n in enumerate(sizes)))


def _probs(space, seed):
    rng = np.random.default_rng(seed)
    parts = []
    for d in space.dims:
        p = rng.dirichlet(np.ones(d.n))
        parts.append(p)
    return np.concatenate(parts)


@given(st.lists(st.integers(2, 6), min_size=1, max_size=5),
       st.integers(0, 10_000), st.floats(0.05, 0.9))
@settings(max_examples=50, deadline=None)
def test_candidates_are_cartesian_product_of_employed(sizes, seed, thresh):
    space = _space(sizes)
    probs = _probs(space, seed)
    cands = enumerate_candidates(space, probs, thresh, max_candidates=10_000)
    groups = space.split_groups(probs)
    expected = 1
    for g in groups:
        expected *= max(int(np.sum(g > thresh)), 1)
    assert cands.shape == (expected, space.n_dims)
    # argmax choice always present
    argmax = np.array([int(np.argmax(g)) for g in groups])
    assert any(np.array_equal(c, argmax) for c in cands)


@given(st.lists(st.integers(2, 8), min_size=2, max_size=6),
       st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_candidate_cap_respected_and_keeps_argmax(sizes, seed):
    space = _space(sizes)
    probs = _probs(space, seed)
    cap = 16
    cands = enumerate_candidates(space, probs, 0.01, max_candidates=cap)
    assert 1 <= cands.shape[0] <= cap
    groups = space.split_groups(probs)
    argmax = np.array([int(np.argmax(g)) for g in groups])
    assert any(np.array_equal(c, argmax) for c in cands)


def test_example_from_paper():
    """PE in {4, 16}, SRAM in {2KB, 8KB} above threshold -> 4 candidates."""
    space = _space([4, 4])
    probs = np.array([0.3, 0.3, 0.2, 0.2,     # two above 0.25
                      0.35, 0.05, 0.35, 0.25])
    cands = enumerate_candidates(space, probs, 0.25, 100)
    assert cands.shape[0] == 2 * 2
