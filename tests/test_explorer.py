"""Candidate enumeration (probability threshold) properties — §6.1."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.encoding import ConfigDim, ConfigSpace
from repro.core.explorer import enumerate_candidates


def _space(sizes):
    return ConfigSpace(dims=tuple(
        ConfigDim(f"d{i}", tuple(float(j) for j in range(n)))
        for i, n in enumerate(sizes)))


def _probs(space, seed):
    rng = np.random.default_rng(seed)
    parts = []
    for d in space.dims:
        p = rng.dirichlet(np.ones(d.n))
        parts.append(p)
    return np.concatenate(parts)


@given(st.lists(st.integers(2, 6), min_size=1, max_size=5),
       st.integers(0, 10_000), st.floats(0.05, 0.9))
@settings(max_examples=50, deadline=None)
def test_candidates_are_cartesian_product_of_employed(sizes, seed, thresh):
    space = _space(sizes)
    probs = _probs(space, seed)
    cands = enumerate_candidates(space, probs, thresh, max_candidates=10_000)
    groups = space.split_groups(probs)
    expected = 1
    for g in groups:
        expected *= max(int(np.sum(g > thresh)), 1)
    assert cands.shape == (expected, space.n_dims)
    # argmax choice always present
    argmax = np.array([int(np.argmax(g)) for g in groups])
    assert any(np.array_equal(c, argmax) for c in cands)


@given(st.lists(st.integers(2, 8), min_size=2, max_size=6),
       st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_candidate_cap_respected_and_keeps_argmax(sizes, seed):
    space = _space(sizes)
    probs = _probs(space, seed)
    cap = 16
    cands = enumerate_candidates(space, probs, 0.01, max_candidates=cap)
    assert 1 <= cands.shape[0] <= cap
    groups = space.split_groups(probs)
    argmax = np.array([int(np.argmax(g)) for g in groups])
    assert any(np.array_equal(c, argmax) for c in cands)


def test_example_from_paper():
    """PE in {4, 16}, SRAM in {2KB, 8KB} above threshold -> 4 candidates."""
    space = _space([4, 4])
    probs = np.array([0.3, 0.3, 0.2, 0.2,     # two above 0.25
                      0.35, 0.05, 0.35, 0.25])
    cands = enumerate_candidates(space, probs, 0.25, 100)
    assert cands.shape[0] == 2 * 2


def _greedy_reference(space, probs, thresh, max_candidates):
    """The original O(overflow x groups x choices) greedy trim loop, kept
    as the behavioural reference for the one-pass argsort trim."""
    import itertools

    from repro.core.explorer import _employed_choices

    groups = [np.asarray(g) for g in space.split_groups(probs)]
    employed = _employed_choices(groups, thresh)

    def product_size(emp):
        s = 1
        for e in emp:
            s *= len(e)
        return s

    while product_size(employed) > max_candidates:
        worst_g, worst_i, worst_p = -1, -1, np.inf
        for gi, (g, e) in enumerate(zip(groups, employed)):
            if len(e) <= 1:
                continue
            am = int(np.argmax(g))
            for ci in e:
                if ci == am:
                    continue
                if g[ci] < worst_p:
                    worst_g, worst_i, worst_p = gi, ci, g[ci]
        if worst_g < 0:
            break
        employed[worst_g] = employed[worst_g][employed[worst_g] != worst_i]

    return np.array(list(itertools.product(*employed)), dtype=np.int32)


def test_argsort_trim_matches_greedy_reference():
    """The single-pass argsort trim pins the greedy loop's exact output,
    including tie order, across seeded spaces and trim-forcing caps."""
    rng = np.random.default_rng(42)
    for seed in range(20):
        sizes = list(rng.integers(2, 8, size=int(rng.integers(2, 6))))
        space = _space(sizes)
        probs = _probs(space, seed)
        for thresh, cap in [(0.01, 1), (0.01, 7), (0.05, 16), (0.2, 1000)]:
            got = enumerate_candidates(space, probs, thresh, cap)
            ref = _greedy_reference(space, probs, thresh, cap)
            np.testing.assert_array_equal(got, ref, err_msg=f"{sizes} {cap}")


def test_argsort_trim_matches_greedy_on_ties():
    """Duplicate probabilities: the stable sort must drop in the same
    group-major order the greedy re-scan visited."""
    space = _space([3, 3, 3])
    probs = np.array([0.5, 0.25, 0.25,
                      0.25, 0.5, 0.25,
                      0.25, 0.25, 0.5])
    for cap in (1, 2, 4, 8, 27):
        got = enumerate_candidates(space, probs, 0.1, cap)
        ref = _greedy_reference(space, probs, 0.1, cap)
        np.testing.assert_array_equal(got, ref, err_msg=f"cap={cap}")


def test_explorer_forward_is_cached_across_instances():
    """Constructing a new Explorer (e.g. per retrain) must reuse the
    module-level compiled G inference, not recompile from scratch."""
    import jax

    from repro.core import gan as G
    from repro.core.explorer import Explorer
    from repro.dataset.generator import generate_dataset
    from repro.design_models.dnnweaver import DnnWeaverModel

    model = DnnWeaverModel()
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=1, neurons=16, batch_size=32)
    ds = generate_dataset(model, 64, seed=0)
    params = G.init_generator(jax.random.PRNGKey(0), cfg, model.space)
    e1 = Explorer(model, ds, params, cfg)
    e2 = Explorer(model, ds, params, cfg)
    assert e1._fwd is e2._fwd
