"""Comparison-harness smoke: the Table-5 experiment runs end-to-end on a
tiny budget, all five methods report through the DSEMethod protocol, and
GANDSE's satisfied-rate beats budget-matched random search."""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "experiments"))

from run_comparison import MODELS, Scale, run_comparison  # noqa: E402


def test_comparison_harness_end_to_end(tmp_path):
    scale = Scale.quick()
    report = run_comparison("dnnweaver", scale, seed=0,
                            results_dir=str(tmp_path))
    rows = {r["method"]: r for r in report["rows"]}
    assert set(rows) == {"GANDSE", "LargeMLP", "DRL", "SA", "RandomSearch"}
    for name, r in rows.items():
        assert r["n_tasks"] == scale.n_tasks, name
        assert np.isfinite(r["dse_time_s"]), name
        assert 0 <= r["n_satisfied"] <= r["n_tasks"], name
    # random search runs at GANDSE's candidate budget
    assert rows["RandomSearch"]["n_candidates"] == pytest.approx(
        max(1, round(rows["GANDSE"]["n_candidates"])))
    # the reproduction's headline claim, at equal evaluation budget
    assert (rows["GANDSE"]["satisfied_rate"]
            >= rows["RandomSearch"]["satisfied_rate"])
    # the Table-5-style report landed on disk
    with open(tmp_path / "comparison_dnnweaver.json") as f:
        emitted = json.load(f)
    assert emitted["model"] == "dnnweaver"
    assert len(emitted["rows"]) == 5


def test_comparison_registry_covers_all_design_models():
    assert set(MODELS) == {"dnnweaver", "im2col", "tpu_mesh"}
    for cls in MODELS.values():
        assert cls().has_jax_oracle     # every model serves the device route
