"""Algorithm-1 training-scheme invariants (the paper's §4 mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.encoding import ConfigDim, ConfigSpace
from repro.core.train import encode_batch, make_train_step, train_gan
from repro.dataset.generator import generate_dataset
from repro.design_models.base import DesignModel


class ConstModel(DesignModel):
    """Design model whose satisfaction is globally constant."""

    name = "const"

    def __init__(self, always_satisfy: bool):
        self.always = always_satisfy
        self.space = ConfigSpace(dims=(ConfigDim("a", (1., 2., 4., 8.)),
                                       ConfigDim("b", (1., 2.))))
        self.net_space = ConfigSpace(dims=(ConfigDim("n", (1., 2.)),))

    def evaluate(self, net, config):
        b = np.broadcast_shapes(net[..., 0].shape, config[..., 0].shape)
        val = 0.5 if self.always else 2.0
        return np.full(b, val), np.full(b, val)


def _mini_cfg(tiny_gan_cfg, model):
    """Shared conftest config factory at this module's historic scale."""
    return tiny_gan_cfg(model, neurons=16, batch_size=32, w_critic=0.5)


def _fake_ds(model, n=64):
    return generate_dataset(model, n, seed=0)


def test_all_satisfied_masks_config_loss(tiny_gan_cfg):
    """When every generated config satisfies (lines 10-12), Loss_config
    contributes 0 and G is driven purely by the critic term."""
    model = ConstModel(always_satisfy=True)
    ds = _fake_ds(model)
    # objectives = 1.0 > 0.5 model output -> always satisfied
    ds.latency[:] = 1.0
    ds.power[:] = 1.0
    st = train_gan(model, ds, _mini_cfg(tiny_gan_cfg, model), iters=1)
    for h in st.history:
        assert h["loss_config"] == pytest.approx(0.0, abs=1e-6)
        assert h["sat_rate"] == pytest.approx(1.0)


def test_none_satisfied_full_config_loss(tiny_gan_cfg):
    model = ConstModel(always_satisfy=False)
    ds = _fake_ds(model)
    ds.latency[:] = 1.0   # model returns 2.0 > 1.0 -> never satisfied
    ds.power[:] = 1.0
    st = train_gan(model, ds, _mini_cfg(tiny_gan_cfg, model), iters=1)
    for h in st.history:
        assert h["loss_config"] > 0.0
        assert h["sat_rate"] == pytest.approx(0.0)


def test_design_model_is_out_of_gradient_path(tiny_gan_cfg):
    """The design model runs through pure_callback; its output enters
    losses only as constants.  If a gradient ever flowed into it, the
    callback (numpy code) would raise under trace."""
    model = ConstModel(always_satisfy=False)
    ds = _fake_ds(model)
    st = train_gan(model, ds, _mini_cfg(tiny_gan_cfg, model), iters=1)
    leaves = jax.tree.leaves(st.g_params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)


def test_d_receives_stop_gradient_probs(tiny_gan_cfg):
    """During the D update the G output is stop_gradient-ed: updating D
    must leave G params bit-identical (alternating updates, Alg. 1)."""
    model = ConstModel(always_satisfy=False)
    ds = _fake_ds(model)
    cfg = _mini_cfg(tiny_gan_cfg, model)
    rng = jax.random.PRNGKey(0)
    g_params = G.init_generator(jax.random.fold_in(rng, 1), cfg, model.space)
    before = jax.tree.map(lambda a: np.asarray(a).copy(), g_params)
    _, _, step = make_train_step(model, cfg)
    # one full alternating step changes g_params through ITS OWN loss only;
    # run with lr=0 for G by zeroing grads is implicit — instead verify
    # numerically that D loss does not depend on g_params:
    d_params = G.init_discriminator(jax.random.fold_in(rng, 2), cfg, model.space)
    batch = {k: jnp.asarray(v) for k, v in
             encode_batch(model, ds, np.arange(16)).items()}
    noise = G.sample_noise(rng, 16, cfg)

    def d_loss_of_g(gp):
        probs = G.generator_apply(gp, model.space, batch["net_enc"],
                                  batch["obj_enc"], noise)
        probs = jax.lax.stop_gradient(probs)
        logits = G.discriminator_apply(d_params, batch["net_enc"], probs,
                                       batch["obj_enc"])
        return jnp.mean(G.satisfaction_ce(logits, jnp.zeros(16)))

    grads = jax.grad(d_loss_of_g)(g_params)
    assert all(float(jnp.max(jnp.abs(g))) == 0.0 for g in jax.tree.leaves(grads))


def test_critic_gradient_flows_through_frozen_d(tiny_gan_cfg):
    """G's critic gradient must be nonzero (it flows THROUGH D into G)."""
    model = ConstModel(always_satisfy=False)
    cfg = _mini_cfg(tiny_gan_cfg, model)
    ds = _fake_ds(model)
    rng = jax.random.PRNGKey(0)
    g_params = G.init_generator(jax.random.fold_in(rng, 1), cfg, model.space)
    d_params = G.init_discriminator(jax.random.fold_in(rng, 2), cfg, model.space)
    batch = {k: jnp.asarray(v) for k, v in
             encode_batch(model, ds, np.arange(16)).items()}
    noise = G.sample_noise(rng, 16, cfg)

    def critic_loss(gp):
        probs = G.generator_apply(gp, model.space, batch["net_enc"],
                                  batch["obj_enc"], noise)
        logits = G.discriminator_apply(d_params, batch["net_enc"], probs,
                                       batch["obj_enc"])
        return jnp.mean(G.satisfaction_ce(logits, jnp.ones(16)))

    grads = jax.grad(critic_loss)(g_params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0


def test_architecture_patterns():
    """Structural checks of the heterogeneous layer patterns."""
    from repro import configs
    g = configs.get_arch("gemma3-1b")
    assert [s.repeats for s in g.segments] == [4, 2]
    assert [sp.cfg.window for sp in g.segments[0].pattern] == [1024] * 5 + [None]
    x = configs.get_arch("xlstm-1.3b")
    kinds = [sp.kind for sp in x.segments[0].pattern]
    assert kinds == ["mlstm"] * 7 + ["slstm"] and x.segments[0].repeats == 6
    h = configs.get_arch("hymba-1.5b")
    assert [s.n_layers for s in h.segments] == [1, 14, 1, 15, 1]
    assert all(sp.cfg.ssm_state == 16 for s in h.segments for sp in s.pattern)
