"""Fused streaming tiled select (core/fused_select): parity pins.

The contract under test: ``fused_select_batch`` returns Selections
bit-identical to the dense route (``enumerate_candidates_batch`` +
``select_batch``) and to the host route (``enumerate_candidates`` +
``select(use_jax=False)``), at ANY tile size — including the adversarial
cases where a streaming implementation can silently diverge:

- candidate counts at tile boundaries (tile-1, tile, tile+1) and ragged
  tails (the last tile partially padded);
- exact metric ties straddling tile boundaries (Algorithm 2 is
  first-wins: the earlier candidate must survive);
- zero-feasible tasks (all-inf oracle -> cfg_idx None) and tasks whose
  first feasible candidate sits mid-tile;
- ragged per-task counts inside one batch.

``MixModel`` keeps every metric an exact small integer in float32, so
the float32 device chains and the float64 host loop make identical
accept decisions — the comparisons below are exact equality, never
almost-equal.  Small moduli force many exact ties.

The mesh test (4 fake devices, shard4 CI job) pins sharded == unsharded
bit-identically: the task axis shards, the tile axis never does.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core import shard
from repro.core.encoding import ConfigDim, ConfigSpace
from repro.core.explorer import (_enum_core, enumerate_candidates,
                                 enumerate_candidates_batch)
from repro.core.fused_select import fused_select_batch
from repro.core.selector import select, select_batch
from repro.design_models.base import DesignModel
from repro.launch.mesh import make_host_mesh

N_DEV = 4
multidevice = pytest.mark.skipif(
    len(jax.devices()) < N_DEV,
    reason=f"needs >= {N_DEV} devices; run with "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={N_DEV}")


def _space(sizes):
    return ConfigSpace(dims=tuple(
        ConfigDim(f"d{k}", tuple(float(v) for v in range(n)))
        for k, n in enumerate(sizes)))


class MixModel(DesignModel):
    """Deterministic synthetic model over an arbitrary space: metrics are
    small-integer hashes of the config values — exact in float32, so
    device (f32) and host (f64) chains agree bit-for-bit.  Small moduli
    force exact metric ties; ``inf_mod`` marks every config whose mix is
    divisible by it infeasible (inf_mod=1 -> nothing feasible)."""

    name = "mix"

    def __init__(self, sizes, lat_mod=61.0, pw_mod=53.0, inf_mod=0.0):
        self.space = _space(sizes)
        self.net_space = ConfigSpace(dims=(ConfigDim("n", (0.0, 1.0)),))
        self._w = np.arange(1, len(sizes) + 1, dtype=np.float64) * 3.0 + 2.0
        self.lat_mod, self.pw_mod, self.inf_mod = lat_mod, pw_mod, inf_mod

    def _mix(self, xp, config):
        s = (config * xp.asarray(self._w, config.dtype)).sum(axis=-1)
        lat = xp.mod(s * 7.0 + 3.0, self.lat_mod) + 1.0
        pw = xp.mod(s * 5.0 + 11.0, self.pw_mod) + 1.0
        if self.inf_mod:
            bad = xp.mod(s, self.inf_mod) == 0
            lat = xp.where(bad, xp.inf, lat)
            pw = xp.where(bad, xp.inf, pw)
        return lat, pw

    def evaluate(self, net, config):
        return self._mix(np, np.asarray(config, np.float64))

    def evaluate_jax(self, net, config):
        return self._mix(jnp, config)


def _probs(model, n_tasks, seed, peak=0.9):
    """Random per-group dirichlet probs (T, onehot_width), scaled so the
    per-group max is `peak` — thresholds then slice ragged employed sets."""
    rng = np.random.default_rng(seed)
    cols = []
    for dim in model.space.dims:
        p = rng.dirichlet(np.ones(len(dim.choices)), size=n_tasks)
        cols.append(p / p.max(axis=1, keepdims=True) * peak)
    return np.concatenate(cols, axis=1).astype(np.float32)


def _routes(model, probs, thresh, cap, lo, po, tile):
    """(fused, dense, host) Selections for the same inputs."""
    t = probs.shape[0]
    net = np.zeros((t, 1), np.int32)
    fused = fused_select_batch(model, net, probs, thresh, cap, lo, po,
                               tile=tile)
    cand, valid, counts = enumerate_candidates_batch(
        model.space, probs, thresh, cap)
    dense = select_batch(model, net, cand, valid, counts, lo, po)
    host = []
    for i in range(t):
        c = enumerate_candidates(model.space, probs[i], thresh, cap)
        host.append(select(model, net[i], c, float(lo[i]), float(po[i]),
                           use_jax=False))
    return fused, dense, host


def _assert_same(a, b):
    assert a.n_candidates == b.n_candidates
    assert a.satisfied == b.satisfied
    if a.cfg_idx is None:
        assert b.cfg_idx is None
        return
    np.testing.assert_array_equal(a.cfg_idx, b.cfg_idx)
    assert a.latency == b.latency and a.power == b.power   # exact, not close


# three fixed models so jit caches are reused across examples
MODELS = {
    "plain": MixModel((5, 4, 3, 4)),
    "ties": MixModel((6, 5, 4), lat_mod=7.0, pw_mod=5.0),
    "holes": MixModel((4, 4, 4, 3), inf_mod=7.0),
}


# ---------------------------------------------------------------------------
# tiled enumeration == host itertools.product at tile boundaries
# ---------------------------------------------------------------------------
def _tiled_enum(space, probs, thresh, cap, tile):
    """Materialize candidates window-by-window with the exact per-tile
    arithmetic of the fused program's tile_step (same ``_enum_core``)."""
    masks_core, radix_core = _enum_core(space)
    keep, counts, total = jax.jit(masks_core)(
        jnp.asarray(probs), jnp.float32(thresh), jnp.int32(cap))
    table, stride = jax.jit(radix_core)(keep, counts)
    total = np.asarray(total)
    out = []
    for t in range(probs.shape[0]):
        rows = []
        for j0 in range(0, int(total[t]), tile):
            j = jnp.arange(j0, j0 + tile, dtype=jnp.int32)
            digit = (j[:, None] // stride[t][None, :]) % counts[t][None, :]
            cand = jnp.take_along_axis(table[t], digit.T, axis=-1).T
            rows.append(np.asarray(cand, np.int32))
        cat = (np.concatenate(rows)[: int(total[t])] if rows
               else np.zeros((0, space.n_dims), np.int32))
        out.append(cat)
    return out


@pytest.mark.parametrize("sizes,thresh", [
    ((7,), 0.0),          # total = tile - 1
    ((8,), 0.0),          # total = tile
    ((3, 3), 0.0),        # total = tile + 1
    ((2, 4), 0.0),        # total = tile, multi-group
    ((5, 4, 3), 0.0),     # 60 = 7 full tiles + ragged 4-row tail
    ((5, 4, 3), 0.35),    # ragged employed sets (threshold slices groups)
])
def test_tiled_enumeration_matches_itertools_product(sizes, thresh):
    space = _space(sizes)
    rng = np.random.default_rng(sum(sizes))
    probs = np.concatenate(
        [rng.uniform(0.4, 1.0, (2, n)).astype(np.float32) for n in sizes],
        axis=1)
    tiled = _tiled_enum(space, probs, thresh, 1 << 12, tile=8)
    for t in range(probs.shape[0]):
        host = enumerate_candidates(space, probs[t], thresh, 1 << 12)
        np.testing.assert_array_equal(tiled[t], host)
        # cross-check the host route really is itertools.product order
        if thresh == 0.0:
            full = np.array(list(itertools.product(
                *[range(n) for n in sizes])), np.int32)
            np.testing.assert_array_equal(host, full)


@given(st.lists(st.integers(1, 6), min_size=1, max_size=4),
       st.integers(0, 2 ** 31 - 1), st.integers(4, 11))
@settings(max_examples=25, deadline=None)
def test_tiled_enumeration_property(sizes, seed, tile):
    """Random spaces, random ragged employed sets, tiles straddling the
    counts every which way — window arithmetic == itertools.product."""
    space = _space(tuple(sizes))
    rng = np.random.default_rng(seed)
    probs = np.concatenate(
        [rng.uniform(0.0, 1.0, (1, n)).astype(np.float32) for n in sizes],
        axis=1)
    (tiled,) = _tiled_enum(space, probs, 0.5, 1 << 12, tile=tile)
    host = enumerate_candidates(space, probs[0], 0.5, 1 << 12)
    np.testing.assert_array_equal(tiled, host)


# ---------------------------------------------------------------------------
# Selection parity: fused == dense == host
# ---------------------------------------------------------------------------
@given(st.sampled_from(sorted(MODELS)), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([0.02, 0.2, 0.5]), st.sampled_from([4, 8, 16]),
       st.floats(2.0, 50.0), st.floats(2.0, 40.0))
@settings(max_examples=20, deadline=None)
def test_fused_dense_host_parity(name, seed, thresh, tile, lo0, po0):
    model = MODELS[name]
    probs = _probs(model, 4, seed)
    rng = np.random.default_rng(seed + 1)
    lo = np.float64(lo0) + rng.integers(0, 8, 4)    # integer-valued: exact
    po = np.float64(po0) + rng.integers(0, 8, 4)    # in f32 like the metrics
    fused, dense, host = _routes(model, probs, thresh, 256, lo, po, tile)
    counts = {s.n_candidates for s in fused}
    for f, d, h in zip(fused, dense, host):
        _assert_same(f, d)
        _assert_same(f, h)
    assert len(counts) >= 1   # ragged batches occur across examples


def test_all_ties_first_candidate_wins_across_tiles():
    """Every candidate identical -> Algorithm 2 accepts only the first
    finite row; a tile reduction that re-orders within a tile (or lets a
    later tile overwrite an equal carry) breaks this."""
    model = MixModel((4, 4, 4), lat_mod=1.0, pw_mod=1.0)   # all (1+s%1)=1.0
    probs = np.full((2, 12), 0.9, np.float32)
    lo = np.array([10.0, 0.5])      # satisfied and unsatisfied regimes
    po = np.array([10.0, 0.5])
    for tile in (3, 4, 64):
        fused, dense, host = _routes(model, probs, 0.1, 256, lo, po, tile)
        for f, d, h in zip(fused, dense, host):
            _assert_same(f, d)
            _assert_same(f, h)
            np.testing.assert_array_equal(f.cfg_idx, [0, 0, 0])


def test_first_feasible_mid_tile_and_zero_feasible():
    """Leading-infeasible runs (winner sits mid-tile / in a later tile)
    and fully infeasible tasks (cfg_idx None, counts still reported)."""
    holes = MixModel((4, 4, 4), inf_mod=2.0)       # ~half the grid infeasible
    dead = MixModel((4, 4, 4), inf_mod=1.0)        # nothing feasible
    probs = _probs(holes, 3, seed=5)
    lo = np.array([20.0, 3.0, 40.0])
    po = np.array([20.0, 3.0, 40.0])
    for tile in (4, 8, 128):
        fused, dense, host = _routes(holes, probs, 0.05, 256, lo, po, tile)
        for f, d, h in zip(fused, dense, host):
            _assert_same(f, d)
            _assert_same(f, h)
    fused, dense, host = _routes(dead, probs, 0.05, 256, lo, po, 8)
    for f, d, h in zip(fused, dense, host):
        assert f.cfg_idx is None and f.n_candidates == h.n_candidates
        _assert_same(f, d)
        _assert_same(f, h)


def test_caps_beyond_dense_limit_accepted():
    """The fused route takes caps past the dense materialization bound
    (2**20); the dense route still refuses them."""
    model = MODELS["plain"]
    probs = _probs(model, 2, seed=9)
    lo = po = np.array([20.0, 20.0])
    net = np.zeros((2, 1), np.int32)
    sels = fused_select_batch(model, net, probs, 0.02, 1 << 26, lo, po,
                              tile=64)
    assert all(s.cfg_idx is not None for s in sels)
    with pytest.raises(AssertionError):
        enumerate_candidates_batch(model.space, probs, 0.02, 1 << 26)


@multidevice
def test_fused_mesh_parity():
    """Task-sharded fused run == single-device fused run, bit-identical
    (the tile axis is never sharded; max(total) becomes an all-reduce)."""
    model = MixModel((6, 5, 4, 3))
    probs = _probs(model, 8, seed=13)
    rng = np.random.default_rng(14)
    lo = np.float64(10.0) + rng.integers(0, 20, 8)
    po = np.float64(10.0) + rng.integers(0, 20, 8)
    net = np.zeros((8, 1), np.int32)
    base = fused_select_batch(model, net, probs, 0.05, 512, lo, po, tile=16)
    with shard.task_mesh(make_host_mesh()):
        sharded = fused_select_batch(model, net, probs, 0.05, 512, lo, po,
                                     tile=16)
    for a, b in zip(base, sharded):
        _assert_same(a, b)
