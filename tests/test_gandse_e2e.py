"""End-to-end GANDSE behaviour (reduced scale): the paper's qualitative
claims hold directionally — see benchmarks/ + EXPERIMENTS.md for the
full-scale reproduction runs."""
import numpy as np
import pytest

from repro.core.dse_api import GANDSE, parse_network, summarize
from repro.core.gan import GANConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel


@pytest.fixture(scope="module")
def trained(tiny_gan_cfg, small_dataset):
    model = DnnWeaverModel()
    cfg = tiny_gan_cfg(model, layers=2, neurons=128, batch_size=256,
                       lr=1e-4, w_critic=1.0)
    g = GANDSE(model, cfg)
    g.train(n_data=0, iters=4, seed=0, ds=small_dataset(model, n=2048))
    return g


def test_training_history_recorded(trained):
    h = trained.state.history
    assert len(h) > 0
    for key in ("loss_g", "loss_d", "loss_config", "loss_critic", "sat_rate"):
        assert key in h[-1]
        assert np.isfinite(h[-1][key])


def test_explore_satisfies_generous_objectives(trained):
    """With 2-3x slack most tasks must be satisfied after short training."""
    tasks = generate_tasks(trained.model, 20, seed=5, slack=(2.0, 3.0))
    res = trained.explore_tasks(tasks)
    s = summarize(res)
    assert s["n_satisfied"] >= 0.6 * s["n_tasks"]
    assert s["dse_time_s"] < 2.0               # negligible-DSE-time claim


def test_emit_config_is_legal(trained):
    tasks = generate_tasks(trained.model, 10, seed=7, slack=(2.0, 3.0))
    res = [r for r in trained.explore_tasks(tasks) if r.satisfied]
    assert res
    art = trained.emit_config(res[0])
    space = trained.model.space
    for dim in space.dims:
        assert art["config"][dim.name] in dim.choices
    assert art["satisfied"]


def test_parse_network_snaps_to_legal_values(trained):
    net = parse_network({"IC": 60, "OC": 33, "OW": 30, "OH": 31,
                         "KW": 3, "KH": 3}, trained.model)
    vals = trained.model.net_space.values_from_indices(net[None])[0]
    assert vals[0] == 64 and vals[1] == 32     # nearest legal
    assert vals[4] == 3


def test_selector_never_worsens_generator_argmax(trained):
    """Algorithm 2 over the candidate set is at least as good as taking
    G's argmax config alone (the candidates include the argmax)."""
    from repro.core.explorer import enumerate_candidates
    from repro.core.selector import select
    tasks = generate_tasks(trained.model, 8, seed=11, slack=(1.2, 2.0))
    for i in range(8):
        net = tasks.net_idx[i]
        lo, po = tasks.lat_obj[i], tasks.pow_obj[i]
        probs = trained._explorer.generator_probs(net, lo, po)[0]
        cands = enumerate_candidates(trained.model.space, probs, 0.2, 4096)
        sel = select(trained.model, net, cands, lo, po)
        argmax = trained.model.space.indices_from_onehot(probs[None])[0]
        la, pa = trained.model.evaluate_indices(net[None], argmax[None])
        argmax_sat = bool(np.isfinite(la[0]) and la[0] <= lo and pa[0] <= po)
        if argmax_sat:
            assert sel.satisfied


def test_dataset_objectives_are_witnessed(small_dataset):
    """Every dataset row's (L, P) is achieved by its own config — the
    (objective, witness) pairing used for training."""
    model = DnnWeaverModel()
    ds = small_dataset(model, n=500, seed=3)
    lat, pw = model.evaluate_indices(ds.net_idx, ds.cfg_idx)
    np.testing.assert_allclose(lat, ds.latency, rtol=1e-12)
    np.testing.assert_allclose(pw, ds.power, rtol=1e-12)
    assert np.isfinite(ds.latency).all()
