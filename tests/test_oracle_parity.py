"""Parity of the fused jnp oracles with the host numpy design models, and
regression of the scanned train loop against the per-batch stepwise loop.

These guard the device-resident Algorithm 1 hot path: if a jnp port drifts
from its numpy twin, or the epoch scan stops reproducing the stepwise
update sequence, the reproduction silently trains against a different
design model than it reports.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.train import (encode_batch, make_epoch_fn, make_oracle,
                              make_train_step, train_gan)
from repro.dataset.generator import generate_dataset
from repro.design_models.base import DesignModel
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel

MODELS = {m.name: m for m in (DnnWeaverModel, Im2colModel, TpuMeshModel)}


# ---------------------------------------------------------------------------
# evaluate_jax == evaluate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(MODELS))
def test_evaluate_jax_matches_numpy(name):
    model = MODELS[name]()
    assert model.has_jax_oracle
    oracle = jax.jit(model.evaluate_jax_indices)
    rng = np.random.default_rng(0)
    lat_all = []
    for seed in range(2):                      # randomized nets AND configs
        net_idx = model.net_space.sample_indices(rng, 256)
        cfg_idx = model.space.sample_indices(rng, 256)
        lat, pw = model.evaluate_indices(net_idx, cfg_idx)
        latj, pwj = oracle(jnp.asarray(net_idx), jnp.asarray(cfg_idx))
        latj = np.asarray(latj, np.float64)
        pwj = np.asarray(pwj, np.float64)
        fin = np.isfinite(lat)
        # feasibility masks (incl. the +inf rows) agree exactly
        np.testing.assert_array_equal(np.isfinite(latj), fin)
        np.testing.assert_array_equal(np.isfinite(pwj), np.isfinite(pw))
        np.testing.assert_allclose(latj[fin], lat[fin], rtol=1e-5)
        np.testing.assert_allclose(pwj[fin], pw[fin], rtol=1e-5)
        lat_all.append(lat)
    if name != "dnnweaver":    # dnnweaver's derived tiles always fit
        assert not np.isfinite(np.concatenate(lat_all)).all(), \
            "sample contained no infeasible rows; +inf parity untested"


def test_evaluate_jax_known_infeasible_is_inf():
    """The hand-built infeasible im2col config is +inf on both routes."""
    model = Im2colModel()
    net = np.array([[256., 256., 64., 64., 5., 5.]])
    cfg = np.array([[4096., 512., 512., 256., 256., 256.,
                     128., 128., 256., 256., 5., 5.]])
    lat, pw = model.evaluate(net, cfg)
    latj, pwj = model.evaluate_jax(jnp.asarray(net), jnp.asarray(cfg))
    assert np.isinf(lat[0]) and np.isinf(pw[0])
    assert np.isinf(float(latj[0])) and np.isinf(float(pwj[0]))


def test_make_oracle_fused_requires_jnp_port():
    class HostOnly(DesignModel):
        name = "host_only"

        def __init__(self):
            m = DnnWeaverModel()
            self.space, self.net_space = m.space, m.net_space

        def evaluate(self, net, config):
            return np.ones(net.shape[0]), np.ones(net.shape[0])

    host = HostOnly()
    assert not host.has_jax_oracle
    _, fused = make_oracle(host)               # auto: falls back to callback
    assert not fused
    with pytest.raises(ValueError):
        make_oracle(host, use_jax_oracle=True)
    _, fused = make_oracle(DnnWeaverModel())   # auto: picks the jnp route
    assert fused


# ---------------------------------------------------------------------------
# Algorithm 2: device scan == host loop
# ---------------------------------------------------------------------------
def test_select_jax_matches_host_loop():
    from repro.core.selector import select

    model = DnnWeaverModel()
    rng = np.random.default_rng(7)
    for _ in range(8):     # enough draws to hit several pow2 pad buckets
        net = model.net_space.sample_indices(rng, 1)[0]
        n_cand = int(rng.integers(1, 80))
        cands = model.space.sample_indices(rng, n_cand).astype(np.int32)
        lat, pw = model.evaluate_indices(
            np.repeat(net[None], n_cand, axis=0), cands)
        # 5% off the quantiles so no objective ties a candidate metric
        # exactly (a tie makes the strict-< chain precision-dependent)
        lo = float(np.quantile(lat, 0.4) * 1.05)
        po = float(np.quantile(pw, 0.6) * 1.05)
        a = select(model, net, cands, lo, po, use_jax=True)
        b = select(model, net, cands, lo, po, use_jax=False)
        assert a.satisfied == b.satisfied
        assert a.n_candidates == b.n_candidates
        np.testing.assert_allclose(a.latency, b.latency, rtol=1e-5)
        np.testing.assert_allclose(a.power, b.power, rtol=1e-5)
        if b.cfg_idx is None:
            assert a.cfg_idx is None
        else:
            np.testing.assert_array_equal(a.cfg_idx, b.cfg_idx)


def test_select_jax_accepts_2d_net_idx():
    """The host route atleast_2d's net_idx; the device route must accept
    the same (1, n_net_dims) shape (auto-routes there for large sets)."""
    from repro.core.selector import select

    model = DnnWeaverModel()
    rng = np.random.default_rng(0)
    net = model.net_space.sample_indices(rng, 1)        # (1, n_dims)
    cands = model.space.sample_indices(rng, 600).astype(np.int32)
    a = select(model, net, cands, 1e-3, 2.0, use_jax=True)
    b = select(model, net, cands, 1e-3, 2.0, use_jax=False)
    assert a.satisfied == b.satisfied
    np.testing.assert_allclose(a.latency, b.latency, rtol=1e-5)


# ---------------------------------------------------------------------------
# the fused step really has no host callback in its program
# ---------------------------------------------------------------------------
def _step_jaxpr(model, cfg, use_jax_oracle):
    ds = generate_dataset(model, 64, seed=0)
    rng = jax.random.PRNGKey(0)
    g_params = G.init_generator(jax.random.fold_in(rng, 1), cfg, model.space)
    d_params = G.init_discriminator(jax.random.fold_in(rng, 2), cfg, model.space)
    g_optim, d_optim, step = make_train_step(model, cfg,
                                             use_jax_oracle=use_jax_oracle)
    batch = {k: jnp.asarray(v)
             for k, v in encode_batch(model, ds, np.arange(32)).items()}
    return str(jax.make_jaxpr(step)(
        g_params, d_params, g_optim.init(g_params), d_optim.init(d_params),
        batch, rng))


def test_fused_step_has_no_pure_callback():
    model = DnnWeaverModel()
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=1, neurons=16, batch_size=32)
    assert "pure_callback" not in _step_jaxpr(model, cfg, None)
    # sanity: the forced-callback route really does go through the host
    assert "pure_callback" in _step_jaxpr(model, cfg, False)


# ---------------------------------------------------------------------------
# scanned epoch == stepwise loop (the seed implementation's trajectory)
# ---------------------------------------------------------------------------
def test_scanned_train_matches_stepwise_loop(tiny_gan_cfg, small_dataset):
    model = DnnWeaverModel()
    ds = small_dataset(model, n=256)
    cfg = tiny_gan_cfg(model, neurons=32, batch_size=64)
    iters, bs = 2, 64

    st = train_gan(model, ds, cfg, iters=iters, seed=0)

    # seed-style reference: one jitted step per batch, host re-encoding,
    # identical rng split and permutation sequence.
    rng = jax.random.PRNGKey(0)
    rng, g_rng, d_rng = jax.random.split(rng, 3)
    g_params = G.init_generator(g_rng, cfg, model.space)
    d_params = G.init_discriminator(d_rng, cfg, model.space)
    g_optim, d_optim, step = make_train_step(model, cfg)
    g_opt, d_opt = g_optim.init(g_params), d_optim.init(d_params)
    np_rng = np.random.default_rng(0)
    ref = []
    for _ in range(iters):
        perm = np_rng.permutation(ds.n)
        for b0 in range(0, ds.n - bs + 1, bs):
            batch = {k: jnp.asarray(v) for k, v in
                     encode_batch(model, ds, perm[b0:b0 + bs]).items()}
            (g_params, d_params, g_opt, d_opt, rng, m) = step(
                g_params, d_params, g_opt, d_opt, batch, rng)
            ref.append({k: float(v) for k, v in m.items()})

    assert len(st.history) == len(ref)
    for got, want in zip(st.history, ref):
        for k, v in want.items():
            np.testing.assert_allclose(got[k], v, rtol=2e-3, atol=1e-4,
                                       err_msg=k)
    # final params agree too (same update sequence, different program)
    leaves = zip(jax.tree.leaves(st.g_params), jax.tree.leaves(g_params))
    for a, b in leaves:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-4)


def test_callback_and_fused_training_agree(tiny_gan_cfg, small_dataset):
    """The oracle switch changes the execution route, not the math."""
    model = DnnWeaverModel()
    ds = small_dataset(model, n=256)
    cfg = tiny_gan_cfg(model, neurons=16, batch_size=64)
    a = train_gan(model, ds, cfg, iters=1, seed=0, use_jax_oracle=True)
    b = train_gan(model, ds, cfg, iters=1, seed=0, use_jax_oracle=False)
    for ra, rb in zip(a.history, b.history):
        for k in ra:
            np.testing.assert_allclose(ra[k], rb[k], rtol=2e-3, atol=1e-4,
                                       err_msg=k)
