"""Crash-safe checkpointing: integrity validation and swap-time recovery.

`test_substrate.py` pins the basic roundtrip/keep-N/partial-write
behavior; this module pins the robustness contract this layer owes the
serving tier:

- checksummed restore: byte corruption in a payload raises
  `CheckpointCorruptionError` (with a message naming the damaged leaf)
  from both `verify` and `restore` — never a silently-garbage tree;
- `restore_latest` skips corrupted steps and lands on the newest valid
  one (the corrupted-params-on-swap recovery path);
- a re-save of an existing step never destroys the previous copy, even
  when the new write blows up mid-flight;
- the saved/restored tree is `GANDSE.attach`-compatible: generator params
  restored from disk produce Selection-identical exploration.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointCorruptionError,
                                      CheckpointManager)
from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.serve.faults import corrupt_checkpoint

MODEL = DnnWeaverModel()


def _tree(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"w": (rng.standard_normal((8, 4)) * scale).astype(np.float32),
            "b": np.arange(4, dtype=np.float32) * scale}


def _assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_corrupted_payload_raises_on_restore_and_verify(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    tree = _tree(0)
    sdir = ck.save(1, tree)
    ck.verify(1)                                    # pristine: passes
    corrupt_checkpoint(sdir, seed=3)
    with pytest.raises(CheckpointCorruptionError) as ei:
        ck.restore(1, tree)
    msg = str(ei.value)
    assert "step 1" in msg and ("checksum mismatch" in msg
                                or "unreadable payload" in msg)
    with pytest.raises(CheckpointCorruptionError):
        ck.verify(1)
    # corruption is detected, not hidden: the step still *lists* (its
    # manifest is intact) so operators can see and inspect the damage
    assert ck.steps() == [1]


def test_restore_latest_skips_corrupted_newest(tmp_path):
    """The swap-time recovery path: newest checkpoint damaged -> fall back
    to the previous good step instead of attaching garbage."""
    ck = CheckpointManager(str(tmp_path), keep_last_n=0)
    good = _tree(1, scale=2.0)
    ck.save(1, _tree(0))
    ck.save(2, good)
    newest = ck.save(3, _tree(2, scale=3.0))
    corrupt_checkpoint(newest, seed=7)
    got = ck.restore_latest(good)
    assert got is not None
    step, tree = got
    assert step == 2
    _assert_tree_equal(tree, good)


def test_restore_latest_none_when_all_corrupted(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    like = _tree(0)
    for s in (1, 2):
        corrupt_checkpoint(ck.save(s, _tree(s)), seed=s)
    assert ck.restore_latest(like) is None


def test_manifest_checksum_tamper_detected(tmp_path):
    """Integrity is two-sided: doctoring the manifest's stored checksum
    (not the payload) must also fail validation."""
    ck = CheckpointManager(str(tmp_path))
    tree = _tree(0)
    ck.save(5, tree)
    mpath = os.path.join(ck._step_dir(5), "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    first = next(iter(manifest["checksums"]))
    manifest["checksums"][first] ^= 0xDEAD
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorruptionError, match=first):
        ck.restore(5, tree)


def test_resave_failure_preserves_previous_copy(tmp_path):
    """Crash mid-re-save of an existing step: the first copy survives
    (the new tree is staged in a temp dir and published by rename, never
    written over the old step in place)."""
    ck = CheckpointManager(str(tmp_path))
    v1 = _tree(0)
    ck.save(1, v1)

    boom = {"w": np.zeros((8, 4), np.float32), "b": _Explodes()}
    with pytest.raises(RuntimeError, match="mid-save crash"):
        ck.save(1, boom)
    _assert_tree_equal(ck.restore(1, v1), v1)       # old copy intact
    # and no stray staging dirs leak into the directory listing
    assert [d for d in os.listdir(tmp_path) if d.startswith(".tmp_")] == []


class _Explodes:
    """A leaf whose array conversion raises — simulates an allocation/IO
    failure partway through writing a new checkpoint."""

    def __array__(self, *a, **kw):
        raise RuntimeError("mid-save crash")


def test_resave_success_replaces_atomically(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(1, _tree(0))
    v2 = _tree(9, scale=5.0)
    ck.save(1, v2)
    _assert_tree_equal(ck.restore(1, v2), v2)
    assert ck.steps() == [1]
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".old_")]
    assert leftovers == []


def test_pre_checksum_checkpoints_still_restore(tmp_path):
    """Back-compat: a manifest without a ``checksums`` key (the old
    format) restores without validation instead of erroring."""
    ck = CheckpointManager(str(tmp_path))
    tree = _tree(0)
    ck.save(1, tree)
    mpath = os.path.join(ck._step_dir(1), "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["checksums"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    _assert_tree_equal(ck.restore(1, tree), tree)


def test_generator_params_roundtrip_attach_parity(tmp_path, tiny_gan_cfg,
                                                  small_dataset):
    """The serving-tier contract end to end: G params saved to disk,
    restored against live params as `like`, re-attached — exploration is
    Selection-identical to the original params."""
    cfg = tiny_gan_cfg(MODEL)
    engine = GANDSE(MODEL, cfg,
                    ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(MODEL, n=256)
    params = G.init_generator(jax.random.PRNGKey(11), cfg, MODEL.space)
    engine.attach(ds, params)
    tasks = generate_tasks(MODEL, 6, seed=4)
    before = engine.explore_tasks(tasks, seed=3)

    ck = CheckpointManager(str(tmp_path))
    ck.save(100, params, extra={"model": MODEL.name})
    assert ck.restore_extra(100)["model"] == MODEL.name
    restored = ck.restore(100, params)
    engine.attach(ds, restored)
    after = engine.explore_tasks(tasks, seed=3)
    for i, (ra, rb) in enumerate(zip(before, after)):
        sa, sb = ra.selection, rb.selection
        assert sa.n_candidates == sb.n_candidates, i
        if sa.cfg_idx is not None:
            np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx)
        assert sa.latency == sb.latency and sa.power == sb.power, i


# ---------------------------------------------------------------------------
# keep_last_n retention (the online loop's steady-disk contract)
# ---------------------------------------------------------------------------
def test_retention_prunes_to_keep_last_n(tmp_path):
    """Every save prunes to the newest keep_last_n steps — payload dirs
    actually deleted, not just de-listed."""
    ck = CheckpointManager(str(tmp_path), keep_last_n=2)
    for s in range(1, 6):
        ck.save(s, _tree(s))
    assert ck.steps() == [4, 5]
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000004", "step_000000005"]
    _assert_tree_equal(ck.restore(5, _tree(5)), _tree(5))


def test_no_prune_on_unverified_save(tmp_path, monkeypatch):
    """Retention is conservative: when the just-saved step fails its own
    verification (torn write, immediate disk damage), nothing is deleted
    — the good history restore_latest falls back on must survive."""
    ck = CheckpointManager(str(tmp_path), keep_last_n=1)
    ck.save(1, _tree(1))

    def bad_verify(step):
        raise CheckpointCorruptionError(f"step {step} damaged")

    monkeypatch.setattr(ck, "verify", bad_verify)
    ck.save(2, _tree(2))             # save lands, but prune is skipped
    assert ck.steps() == [1, 2]      # step 1 survives the unverified save


def test_torn_prune_crash_leaves_consistent_state(tmp_path, monkeypatch):
    """Crash mid-prune (after the aside rename, before the delete): the
    pruned step is atomically de-listed — steps() stays consistent and
    restore_latest works — and the orphaned aside dir is swept by the
    next save instead of leaking forever."""
    import repro.checkpoint.manager as M

    ck = CheckpointManager(str(tmp_path), keep_last_n=1)
    ck.save(1, _tree(1))
    real = M.shutil.rmtree
    calls = {"prune": 0}

    def flaky(path, **kw):
        if os.path.basename(path).startswith(".prune_"):
            calls["prune"] += 1
            if calls["prune"] >= 2:     # the post-rename delete
                raise OSError("disk error mid-prune")
        return real(path, **kw)

    monkeypatch.setattr(M.shutil, "rmtree", flaky)
    with pytest.raises(OSError, match="mid-prune"):
        ck.save(2, _tree(2))
    # the new step is fully published and restorable; the half-pruned
    # one is de-listed (never a listed step with half a payload)
    assert ck.steps() == [2]
    step, tree = ck.restore_latest(_tree(2))
    assert step == 2
    _assert_tree_equal(tree, _tree(2))
    assert any(d.startswith(".prune_") for d in os.listdir(tmp_path))

    monkeypatch.setattr(M.shutil, "rmtree", real)
    ck.save(3, _tree(3))                 # sweeps the orphaned aside dir
    assert ck.steps() == [3]
    assert [d for d in os.listdir(tmp_path)
            if d.startswith((".prune_", ".old_step_"))] == []
