"""The fused-MLP fast path, end to end on CPU via interpret mode.

The dispatch rule (kernels/dispatch.py) keeps CPU CI on the jnp reference,
so these tests drive the *actual Pallas kernels* through the jitted
consumers with ``dispatch.force_interpret()`` — the same kernel code TPU
compiles — and pin:

- Algorithm 1: one fused train step == one unfused step (params, metrics);
- Explorer: the megakernel (chained) G forward == the vmap route;
- LargeMLP baseline: same for its noise-averaged forward;
- nn.mlp_apply: the non-ReLU-activation contract (raise on explicit
  use_fused=True, honored fallback on auto) and fused/unfused parity;
- DSEServer: the ServeConfig.use_fused override reaches the engine.

Caution for new tests: ``_cached_fwd`` memoizes jitted forwards on
(space, gan_cfg, chained) — traces taken under force_interpret stay
interpret-routed for that key, so interpret-mode traces here always use a
config with ``use_fused=True`` (a key the non-interpret tests never use).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gan as G
from repro.core import train as T
from repro.core.dse_api import GANDSE
from repro.core.explorer import _cached_fwd, task_keys
from repro.dataset.generator import generate_dataset
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.kernels import dispatch as D
from repro.nn import layers as L


@pytest.fixture(scope="module")
def setup(small_dataset):
    model = DnnWeaverModel()
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=2, neurons=32, batch_size=32, lr=1e-3)
    ds = small_dataset(model, n=128)
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    gp = G.init_generator(r1, cfg, model.space)
    dp = G.init_discriminator(r2, cfg, model.space)
    batch = {k: jnp.asarray(v)
             for k, v in T.encode_batch(model, ds, np.arange(32)).items()}
    return model, cfg, ds, gp, dp, batch, r3


def _tree_close(a, b, rtol=1e-4, atol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=rtol, atol=atol), a, b)


# ---------------------------------------------------------------------------
# nn.mlp_apply contract (the old silent-ignore bug)
# ---------------------------------------------------------------------------
def test_mlp_apply_fused_rejects_non_relu(rng):
    params = L.mlp_init(jax.random.PRNGKey(0), 8, [16, 16], 4)
    x = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    with pytest.raises(ValueError, match="relu"):
        L.mlp_apply(params, x, activation=jnp.tanh, use_fused=True)


def test_mlp_apply_auto_falls_back_for_non_relu(rng):
    """use_fused=None + non-ReLU activation: the activation is honored via
    the unfused path (it used to be silently replaced by ReLU when the
    fused route was taken)."""
    params = L.mlp_init(jax.random.PRNGKey(0), 8, [16, 16], 4)
    x = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    got = L.mlp_apply(params, x, activation=jnp.tanh)
    h = x
    for p in params["layers"][:-1]:
        h = jnp.tanh(h @ p["w"] + p["b"])
    want = h @ params["layers"][-1]["w"] + params["layers"][-1]["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # even under the interpret hook / explicit interpret=True the fallback
    # holds — the activation must never be replaced by the kernel's ReLU
    with D.force_interpret():
        got2 = L.mlp_apply(params, x, activation=jnp.tanh)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    got3 = L.mlp_apply(params, x, activation=jnp.tanh, interpret=True)
    np.testing.assert_allclose(np.asarray(got3), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_mlp_apply_fused_interpret_parity(rng):
    params = L.mlp_init(jax.random.PRNGKey(1), 12, [24, 24], 6)
    x = jnp.asarray(rng.normal(size=(7, 12)), jnp.float32)
    want = L.mlp_apply(params, x)
    got = L.mlp_apply(params, x, use_fused=True, interpret=True)
    chained = L.mlp_apply_chained(params, x, use_fused=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(chained), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Algorithm 1 through the fused kernels
# ---------------------------------------------------------------------------
def test_train_step_fused_interpret_parity(setup):
    """One full Algorithm 1 step (G and D updates, so forward AND custom
    -vjp backward kernels) in interpret-fused mode == the jnp step."""
    model, cfg, ds, gp, dp, batch, rng = setup

    def one_step():
        g_optim, d_optim, step = T.make_train_step(model, cfg)
        go, do = g_optim.init(gp), d_optim.init(dp)
        return step(gp, dp, go, do, batch, rng)

    g_ref, d_ref, *_, m_ref = one_step()
    # spy on the kernel entry so this test can never silently degrade into
    # comparing the jnp route against itself
    import repro.kernels.fused_mlp as FM
    orig, seen = FM.fused_dense, []
    FM.fused_dense = lambda *a, **k: (seen.append(k), orig(*a, **k))[1]
    try:
        with D.force_interpret():
            g_fus, d_fus, *_, m_fus = one_step()
    finally:
        FM.fused_dense = orig
    assert seen and all(k.get("interpret") for k in seen), \
        "the fused-interpret route was not engaged"
    for k in m_ref:
        np.testing.assert_allclose(float(m_ref[k]), float(m_fus[k]),
                                   rtol=1e-4, atol=1e-5, err_msg=k)
    _tree_close(g_ref, g_fus)
    _tree_close(d_ref, d_fus)


# ---------------------------------------------------------------------------
# Explorer inference routes
# ---------------------------------------------------------------------------
def test_explorer_chained_route_parity(setup):
    """The flattened megakernel route == the vmap route (same per-task
    noise streams), on both the jnp fallback and the interpret kernels."""
    model, cfg, ds, gp, dp, batch, rng = setup
    net_enc = jnp.asarray(ds.net_encoded(model, ds.net_idx[:5]))
    obj_enc = jnp.asarray(ds.obj_encoded(ds.latency[:5], ds.power[:5]))
    keys = task_keys(7, 5)

    p_vmap = _cached_fwd(model.space, cfg, chained=False)(
        gp, net_enc, obj_enc, keys, n_samples=3)
    p_chain = _cached_fwd(model.space, cfg, chained=True)(
        gp, net_enc, obj_enc, keys, n_samples=3)
    np.testing.assert_allclose(np.asarray(p_vmap), np.asarray(p_chain),
                               rtol=1e-5, atol=1e-6)

    fused_cfg = dataclasses.replace(cfg, use_fused=True)
    with D.force_interpret():
        p_kernel = _cached_fwd(model.space, fused_cfg, chained=True)(
            gp, net_enc, obj_enc, keys, n_samples=3)
    np.testing.assert_allclose(np.asarray(p_vmap), np.asarray(p_kernel),
                               rtol=1e-4, atol=1e-5)


def test_large_mlp_chained_route_parity(rng):
    from repro.baselines.mlp import LargeMLP, _cached_fwd as mlp_fwd
    from repro.design_models.dnnweaver import DnnWeaverModel

    model = DnnWeaverModel()
    mlp = LargeMLP(model, hidden_layers=2, neurons=24)
    params = mlp.init_params(seed=0)
    t = 4
    net_enc = jnp.asarray(rng.normal(size=(t, model.net_space.n_dims)),
                          jnp.float32)
    obj_enc = jnp.asarray(rng.normal(size=(t, 2)), jnp.float32)
    keys = task_keys(11, t)
    _, f_vmap = mlp_fwd(model.space, mlp.noise_dim, None, False)
    _, f_chain = mlp_fwd(model.space, mlp.noise_dim, None, True)
    p1 = f_vmap(params, net_enc, obj_enc, keys, n_samples=2)
    p2 = f_chain(params, net_enc, obj_enc, keys, n_samples=2)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-5, atol=1e-6)
    with D.force_interpret():
        _, f_kernel = mlp_fwd(model.space, mlp.noise_dim, True, True)
        p3 = f_kernel(params, net_enc, obj_enc, keys, n_samples=2)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p3),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# serve-layer override
# ---------------------------------------------------------------------------
def test_serve_use_fused_override_reaches_engine(setup):
    from repro.serve import DSEServer, ServeConfig

    model, cfg, ds, gp, dp, batch, rng = setup
    engine = GANDSE(model, cfg)
    engine.attach(ds, gp)
    srv = DSEServer(ServeConfig(max_batch=4, use_fused=False))
    srv.register(engine)
    assert engine.gan_cfg.use_fused is False
    s = srv.summary()
    assert s["kernels"]["fused"][model.name] is False
    assert "backend" in s["kernels"]
    # the engine still serves correctly after the override re-attach:
    # same Selection as a direct dispatch through the same batched route
    from repro.dataset.generator import DSETask

    rid = srv.submit(model.name, ds.net_idx[0], float(ds.latency[0] * 2),
                     float(ds.power[0] * 2), seed=3)
    srv.drain()
    resp = srv.response(rid)
    assert resp is not None and resp.result is not None
    task = DSETask.single(ds.net_idx[0], float(ds.latency[0] * 2),
                          float(ds.power[0] * 2))
    want = engine.explore_tasks(task, seed=3)[0]
    np.testing.assert_array_equal(resp.result.selection.cfg_idx,
                                  want.selection.cfg_idx)
    assert resp.result.selection.satisfied == want.selection.satisfied


def test_gandse_set_use_fused_rebuilds_explorer(setup):
    model, cfg, ds, gp, dp, batch, rng = setup
    engine = GANDSE(model, cfg)
    engine.attach(ds, gp)
    before = engine._explorer
    engine.set_use_fused(False)
    assert engine.gan_cfg.use_fused is False
    assert engine._explorer is not before
    assert engine._explorer.g_params is gp
