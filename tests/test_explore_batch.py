"""Batched-vs-sequential exploration parity — the `explore_batch` contract.

`GANDSE.explore_batch` must return the same Selection (cfg_idx, latency,
power, satisfied, n_candidates) as the looped `explore`, for all three
design models, including tasks with zero feasible candidates and ragged
candidate counts across the batch.  Also pins the device candidate
enumeration to the host route and the (T, C) oracle broadcast contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import (ExplorerConfig, enumerate_candidates,
                                 enumerate_candidates_batch)
from repro.dataset.generator import generate_tasks
from repro.design_models.base import DesignModel
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel

MODELS = {m.name: m for m in (DnnWeaverModel, Im2colModel, TpuMeshModel)}


@pytest.fixture(scope="module")
def models():
    """Shared instances: the per-instance Algorithm 2 jit caches survive
    across this module's tests, keeping tier-1 compile time down."""
    return {name: cls() for name, cls in MODELS.items()}


def _attached(model, tiny_gan_cfg, small_dataset, thresh=0.1, cap=128,
              ds_model=None):
    """GANDSE with a random-init generator: exploration parity does not
    depend on training quality, and skipping train() keeps tier-1 fast."""
    cfg = tiny_gan_cfg(model)
    g = GANDSE(model, cfg,
               ExplorerConfig(prob_threshold=thresh, max_candidates=cap))
    ds = small_dataset(ds_model or model, n=256)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    return g


def _assert_selection_equal(name, i, sa, sb):
    assert sa.n_candidates == sb.n_candidates, (name, i)
    assert (sa.cfg_idx is None) == (sb.cfg_idx is None), (name, i)
    if sa.cfg_idx is not None:
        np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx, err_msg=f"{name}[{i}]")
    assert sa.latency == sb.latency and sa.power == sb.power, (name, i)
    assert sa.satisfied == sb.satisfied, (name, i)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_explore_batch_matches_sequential(name, models, tiny_gan_cfg,
                                          small_dataset):
    model = models[name]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    tasks = generate_tasks(model, 6, seed=2)
    batched = g.explore_batch(tasks, seed=7)
    seq = [g.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                     seed=7 + i) for i in range(6)]
    counts = {r.selection.n_candidates for r in batched}
    assert len(counts) > 1, "seeds no longer produce ragged candidate counts"
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal(name, i, a.selection, b.selection)
    # explore_tasks routes through the same batched path by default
    routed = g.explore_tasks(tasks, seed=7)
    for i, (a, b) in enumerate(zip(routed, batched)):
        _assert_selection_equal(name, i, a.selection, b.selection)


class _InfeasibleModel(DnnWeaverModel):
    """Every config infeasible: the zero-feasible-candidates edge case."""

    name = "dnnweaver_infeasible"

    def evaluate(self, net, config):
        lat, pw = super().evaluate(net, config)
        return np.full_like(lat, np.inf), np.full_like(pw, np.inf)

    def evaluate_jax(self, net, config):
        lat, pw = super().evaluate_jax(net, config)
        return jnp.full_like(lat, jnp.inf), jnp.full_like(pw, jnp.inf)


def test_explore_batch_zero_feasible(models, tiny_gan_cfg, small_dataset):
    # T=6 / seed=2 on the dnnweaver space: identical shapes to the parity
    # test above, so the enumeration/forward programs are jit-cache hits
    model = _InfeasibleModel()
    g = _attached(model, tiny_gan_cfg, small_dataset,
                  ds_model=models["dnnweaver"])
    tasks = generate_tasks(models["dnnweaver"], 6, seed=2)
    batched = g.explore_batch(tasks, seed=7)
    seq = [g.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                     seed=7 + i) for i in range(6)]
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal("infeasible", i, a.selection, b.selection)
        assert a.selection.cfg_idx is None and not a.selection.satisfied
        assert a.selection.n_candidates > 0      # candidates existed...
        assert a.selection.latency == np.inf     # ...none were feasible


class _HostOnlyModel(DnnWeaverModel):
    """jnp oracle hidden: exercises the automatic sequential fallback."""

    name = "dnnweaver_host_only"
    evaluate_jax = DesignModel.evaluate_jax


def test_explore_batch_falls_back_without_jax_oracle(models, tiny_gan_cfg,
                                                     small_dataset):
    model = _HostOnlyModel()
    assert not model.has_jax_oracle
    g = _attached(model, tiny_gan_cfg, small_dataset,
                  ds_model=models["dnnweaver"])
    tasks = generate_tasks(models["dnnweaver"], 6, seed=2)
    batched = g.explore_batch(tasks, seed=7)
    seq = [g.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                     seed=7 + i) for i in range(6)]
    for i, (a, b) in enumerate(zip(batched, seq)):
        _assert_selection_equal("host_only", i, a.selection, b.selection)
    assert any(r.selection.cfg_idx is not None for r in batched)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_enumeration_batch_matches_host(name, models):
    """Device mixed-radix enumeration == host itertools.product, per task,
    across thresholds and caps (including trim-forcing caps)."""
    space = models[name].space
    rng = np.random.default_rng(0)
    probs = np.stack([
        np.concatenate([rng.dirichlet(np.ones(d.n) * rng.uniform(0.3, 3.0))
                        for d in space.dims]).astype(np.float32)
        for _ in range(6)       # T=6 everywhere: shapes hit the jit cache
    ])
    for thresh, cap in [(0.2, 4096), (0.05, 64), (0.02, 1)]:
        cand, valid, counts = enumerate_candidates_batch(space, probs,
                                                         thresh, cap)
        cand, valid = np.asarray(cand), np.asarray(valid)
        for t in range(probs.shape[0]):
            host = enumerate_candidates(space, probs[t], thresh, cap)
            assert counts[t] == host.shape[0] == valid[t].sum(), (thresh, cap)
            np.testing.assert_array_equal(cand[t, :counts[t]], host)


@pytest.mark.parametrize("n_groups,lim_name", [(8, "_DENSE_LIM"),
                                               (10, "_PROD_LIM")])
def test_enumeration_trim_at_cap_limit(n_groups, lim_name):
    """cap == the route limit (dense 2**20, fused 2**26) must still trim on
    device: the product clamp sits strictly above the cap (regression:
    clamping AT the cap made `> cap` unsatisfiable, disabling the trim and
    allocating the untrimmed cartesian product).  8 groups of 8 (2**24)
    overflows the dense limit, 10 groups (2**30) the fused one; checked at
    the mask level so the test never materializes the candidate tensor."""
    import repro.core.explorer as explorer
    from repro.core.encoding import ConfigDim, ConfigSpace
    from repro.core.explorer import _batched_enum_fns, _trimmed_employed

    space = ConfigSpace(dims=tuple(
        ConfigDim(f"d{i}", tuple(float(j) for j in range(8)))
        for i in range(n_groups)))               # product 8**n >> cap
    rng = np.random.default_rng(0)
    probs = np.concatenate([rng.dirichlet(np.ones(8))
                            for _ in range(n_groups)]
                           ).astype(np.float32)[None]
    cap = getattr(explorer, lim_name)
    assert 8 ** n_groups > cap                   # the trim must engage
    masks_fn, _ = _batched_enum_fns(space)
    keep, counts, total = masks_fn(jnp.asarray(probs), jnp.float32(0.01),
                                   jnp.int32(cap))
    total = int(np.asarray(total)[0])
    employed = _trimmed_employed(space, probs[0], 0.01, cap)
    want = 1
    for e in employed:
        want *= len(e)
    assert want <= cap and total == want
    keep = np.asarray(keep[0])
    for g, e in enumerate(employed):
        np.testing.assert_array_equal(np.flatnonzero(keep[g]), e)


def test_task_keys_survive_large_seeds(models, tiny_gan_cfg, small_dataset):
    """Per-task noise keys must come from a host int64 sum: the legacy
    `seed + jnp.arange(T)` int32 route raised OverflowError for Python-int
    seeds >= 2**31 and aliased wrapped sums with other seeds' keys."""
    from repro.core.explorer import task_keys

    # bitwise parity with the legacy int32 route wherever it worked
    for seed in (0, 7, 12345, 2**31 - 9):
        legacy = jax.vmap(jax.random.PRNGKey)(seed + jnp.arange(8))
        np.testing.assert_array_equal(np.asarray(task_keys(seed, 8)),
                                      np.asarray(legacy))
    # seeds >= 2**31 used to raise at dispatch; now valid and collision-free
    big = 2**31
    keys = np.asarray(task_keys(big, 8))
    assert len({tuple(k) for k in keys}) == 8
    # the batched-vs-sequential parity contract extends to large seeds
    g = _attached(models["dnnweaver"], tiny_gan_cfg, small_dataset)
    tasks = generate_tasks(models["dnnweaver"], 6, seed=2)
    batched = g.explore_batch(tasks, seed=big)
    for i in (0, 3, 5):
        r = g.explore(tasks.net_idx[i], tasks.lat_obj[i], tasks.pow_obj[i],
                      seed=big + i)
        _assert_selection_equal("large_seed", i, batched[i].selection,
                                r.selection)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_oracle_broadcasts_task_by_candidate_grids(name, models):
    """(T, 1, n_net) x (T, C, n_cfg) -> (T, C): one grid call equals the
    stacked per-task calls, on both the jnp and numpy oracles.  (Eager jnp:
    the jitted grid shape is compiled and exercised by select_batch in
    test_explore_batch_matches_sequential; this pins the broadcast math
    without paying two more XLA compiles per model.)"""
    model = models[name]
    rng = np.random.default_rng(1)
    T, C = 4, 16
    net_idx = model.net_space.sample_indices(rng, T)
    cfg_idx = np.stack([model.space.sample_indices(rng, C) for _ in range(T)])
    latj, pwj = model.evaluate_jax_indices(jnp.asarray(net_idx[:, None, :]),
                                           jnp.asarray(cfg_idx))
    lat, pw = model.evaluate_indices(net_idx[:, None, :], cfg_idx)
    assert latj.shape == pwj.shape == lat.shape == (T, C)
    for t in range(T):
        lat_t, pw_t = model.evaluate_indices(
            np.repeat(net_idx[t][None], C, axis=0), cfg_idx[t])
        np.testing.assert_array_equal(lat[t], lat_t)
        np.testing.assert_array_equal(pw[t], pw_t)
        latj_t, pwj_t = model.evaluate_jax_indices(
            jnp.asarray(net_idx[t][None]), jnp.asarray(cfg_idx[t]))
        np.testing.assert_array_equal(np.asarray(latj[t]), np.asarray(latj_t))
        np.testing.assert_array_equal(np.asarray(pwj[t]), np.asarray(pwj_t))
