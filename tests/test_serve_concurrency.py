"""Thread-safety of the serving primitives: `MicroBatcher` and
`ResultCache` under adversarial interleavings.

These are engine-free tests (no JAX compute): barrier-started threads
hammer admit/pop/requeue/shed and get/put/invalidate concurrently, and the
assertions are conservation laws — every admitted rid leaves the batcher
exactly once (popped XOR shed, never lost, never duplicated), per-thread
FIFO order survives, and the cache's LRU bound, stat counters, and stored
values stay consistent.  A property test (hypothesis, or the repo's
seeded-random `_mini_hypothesis` fallback) varies thread/batch geometry.

The stale-cache-after-swap tests pin the params-generation stamp contract
on a stub engine: a batch that executes across a `swap` still answers,
but its results must never re-enter the cache the swap just invalidated.
"""
import threading
from collections import Counter

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.dse_api import DSEResult
from repro.core.selector import Selection
from repro.serve import (DSERequest, DSEServer, MicroBatcher, ResultCache,
                         ServeConfig)

_NET = np.array([1, 2, 3], np.int64)


def _req(rid, model="m0", seed=None, deadline=None):
    return DSERequest(rid=rid, model_name=model, net_idx=_NET,
                      lat_obj=1.0, pow_obj=2.0,
                      seed=rid if seed is None else seed, deadline=deadline)


def _run_threads(fns):
    """Start one thread per fn behind a common barrier (maximally
    simultaneous release) and join them all; re-raises the first error."""
    barrier = threading.Barrier(len(fns))
    errors = []

    def wrap(fn):
        def run():
            barrier.wait()
            try:
                fn()
            except BaseException as e:    # pragma: no cover - surfaced below
                errors.append(e)
        return run

    threads = [threading.Thread(target=wrap(fn)) for fn in fns]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not any(t.is_alive() for t in threads), "thread wedged"
    if errors:
        raise errors[0]
    return errors


def test_concurrent_admit_and_pop_conserves_requests():
    """4 admitters racing 2 poppers: every rid crosses the batcher exactly
    once, and each admitter's own rids come out in its submission order
    (per-source FIFO is what the single queue lock must preserve)."""
    n_threads, n_each = 4, 200
    batcher = MicroBatcher(max_batch=7)
    popped = []
    pop_lock = threading.Lock()
    total = n_threads * n_each

    def admitter(k):
        def run():
            for i in range(n_each):
                batcher.admit(_req(k * n_each + i))
        return run

    def popper():
        def run():
            while True:
                with pop_lock:
                    if len(popped) >= total:
                        return
                    b = batcher.next_batch()
                    if b is not None:
                        popped.extend(r.rid for r in b.requests)
        return run

    _run_threads([admitter(k) for k in range(n_threads)]
                 + [popper(), popper()])
    assert len(popped) == total and len(set(popped)) == total
    assert batcher.pending() == 0
    for k in range(n_threads):                  # per-admitter FIFO
        mine = [r for r in popped if k * n_each <= r < (k + 1) * n_each]
        assert mine == sorted(mine)


def test_concurrent_requeue_front_loses_nothing():
    """Dispatch-failure recovery under contention: poppers that requeue
    every other batch (simulating failed dispatches) racing an admitter —
    conservation still holds and nothing is double-delivered."""
    batcher = MicroBatcher(max_batch=5)
    n = 300
    delivered = []
    lock = threading.Lock()

    def admitter():
        for i in range(n):
            batcher.admit(_req(i))

    def flaky_popper():
        fail_next = True
        while True:
            with lock:
                if len(delivered) >= n:
                    return
                b = batcher.next_batch()
                if b is None:
                    continue
                if fail_next:
                    batcher.requeue_front(b.requests)   # "dispatch failed"
                else:
                    delivered.extend(r.rid for r in b.requests)
                fail_next = not fail_next

    _run_threads([admitter, flaky_popper, flaky_popper])
    assert sorted(delivered) == list(range(n))
    assert batcher.pending() == 0


def test_concurrent_shed_admit_pop_partition():
    """shed() racing admit/pop: every admitted rid ends up in exactly one
    of {popped, shed, still-queued} — the load-shedding path can never
    lose a request or deliver it twice."""
    batcher = MicroBatcher(max_batch=4)
    n = 400
    popped, shed = [], []
    lock = threading.Lock()
    done = threading.Event()

    def admitter():
        for i in range(n):
            # odd rids are shed-eligible (the predicate below)
            batcher.admit(_req(i))
        done.set()

    def popper():
        while not (done.is_set() and batcher.pending() == 0):
            b = batcher.next_batch()
            if b is not None:
                with lock:
                    popped.extend(r.rid for r in b.requests)

    def shedder():
        while not (done.is_set() and batcher.pending() == 0):
            out = batcher.shed(lambda r: r.rid % 2 == 1)
            with lock:
                shed.extend(r.rid for r in out)

    _run_threads([admitter, popper, shedder])
    leftovers = []
    while True:
        b = batcher.next_batch()
        if b is None:
            break
        leftovers.extend(r.rid for r in b.requests)
    counts = Counter(popped) + Counter(shed) + Counter(leftovers)
    assert counts == Counter(range(n))          # exactly-once partition
    assert all(r % 2 == 1 for r in shed)        # predicate respected


def test_concurrent_cache_put_get_invalidate():
    """Writers, readers, and an invalidator hammering one ResultCache:
    no lost updates visible as wrong values (a hit for key k always
    returns the value put under k), the capacity bound holds throughout,
    and the hit/miss counters exactly partition the reads."""
    cache = ResultCache(capacity=32)
    n_keys, n_rounds = 64, 150
    values = {k: f"v{k}" for k in range(n_keys)}
    reads = Counter()
    lock = threading.Lock()

    def writer(offset):
        def run():
            for i in range(n_rounds):
                k = (i + offset) % n_keys
                cache.put(("m", k), values[k])
                assert len(cache) <= 32
        return run

    def reader():
        hits = misses = 0
        for i in range(n_rounds * 2):
            k = i % n_keys
            got = cache.get(("m", k))
            if got is None:
                misses += 1
            else:
                hits += 1
                assert got == values[k]         # never a torn/foreign value
        with lock:
            reads["hits"] += hits
            reads["misses"] += misses

    def invalidator():
        for _ in range(20):
            cache.invalidate_model("other")     # no-op model: exercises scan
        cache.invalidate_model("m")

    _run_threads([writer(0), writer(17), reader, reader, invalidator])
    s = cache.stats()
    assert s["size"] <= s["capacity"] == 32
    assert s["hits"] == reads["hits"] and s["misses"] == reads["misses"]
    assert s["hits"] + s["misses"] == 2 * n_rounds * 2


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=60))
def test_property_admit_pop_conservation(n_admitters, max_batch, n_each):
    """Property: for any (thread count, batch size, load) geometry, the
    batcher delivers each admitted request exactly once and drains to
    empty."""
    batcher = MicroBatcher(max_batch=max_batch)
    total = n_admitters * n_each
    popped = []
    lock = threading.Lock()

    def admitter(k):
        def run():
            for i in range(n_each):
                batcher.admit(_req(k * n_each + i))
        return run

    def popper():
        while True:
            with lock:
                if len(popped) >= total:
                    return
                b = batcher.next_batch()
                if b is not None:
                    popped.extend(r.rid for r in b.requests)

    _run_threads([admitter(k) for k in range(n_admitters)] + [popper])
    assert sorted(popped) == list(range(total))
    assert batcher.pending() == 0


def test_batch_formation_under_concurrency_is_well_formed():
    """Micro-batches popped during a race are still internally consistent:
    pow2-padded sizes, seeds aligned with requests, one model per batch."""
    batcher = MicroBatcher(max_batch=6)
    n = 120
    batches = []
    lock = threading.Lock()

    def admitter(model):
        def run():
            for i in range(n):
                batcher.admit(_req(i, model=model))
        return run

    def popper():
        got = 0
        while got < 2 * n:
            b = batcher.next_batch()
            if b is None:
                with lock:
                    got = sum(x.n_real for x in batches)
                continue
            with lock:
                batches.append(b)
                got = sum(x.n_real for x in batches)

    _run_threads([admitter("a"), admitter("b"), popper])
    assert sum(b.n_real for b in batches) == 2 * n
    for b in batches:
        assert b.padded_size >= b.n_real
        assert (b.padded_size & (b.padded_size - 1)) == 0   # pow2 bucket
        assert len(b.seeds) == b.padded_size
        np.testing.assert_array_equal(
            b.seeds[: b.n_real], [r.seed for r in b.requests])
        assert len({r.model_name for r in b.requests}) == 1


# ---------------------------------------------------------------------------
# the stale-cache-after-swap race (params-generation stamp contract)
# ---------------------------------------------------------------------------
class _StubSpace:
    n_dims = 3
    group_sizes = (8, 8, 8)


class _StubModel:
    name = "stub"
    net_space = _StubSpace()


class _StubEngine:
    """Engine whose Selections encode which params version computed them:
    latency == the params tag attached at the time of explore_tasks."""

    method_name = "stub"

    def __init__(self):
        self.model = _StubModel()
        self.params_tag = 0.0

    def attach(self, ds, g_params):
        self.params_tag = float(g_params)

    def explore_tasks(self, tasks, seed=0, batched=None):
        tag = self.params_tag
        return [
            DSEResult(Selection(np.zeros(3, np.int64), tag, tag, True, 1),
                      float(tasks.lat_obj[i]), float(tasks.pow_obj[i]), 0.0)
            for i in range(len(tasks))
        ]


def _stub_server(**kw):
    srv = DSEServer(ServeConfig(max_batch=4, **kw))
    srv.register(_StubEngine())
    return srv


def test_swap_between_execute_and_publish_skips_cache():
    """THE race, deterministically interleaved: form -> execute -> swap ->
    publish.  The response still answers (old params — the documented
    in-flight semantics), but the result must NOT enter the cache the
    swap just invalidated: a later identical submit must re-dispatch and
    see the new params, not the retired Selection."""
    srv = _stub_server()
    rid = srv.submit("stub", _NET, 1.0, 2.0, seed=7)
    batch = srv.form_batch()
    assert batch is not None
    results, info = srv.execute_batch(batch)       # old params (tag 0.0)
    n_inval = srv.swap("stub", ds=None, g_params=1.0)   # swap lands mid-flight
    assert n_inval == 0                            # nothing cached yet
    srv.publish_batch(batch, results, info)

    resp = srv.response(rid)
    assert resp.ok and resp.result.selection.latency == 0.0  # answered (old)
    assert srv.stats["stale_cache_skips"] == 1
    # the poisoning the stamp prevents: an identical re-ask must NOT hit
    # the cache with the old-params Selection
    rid2 = srv.submit("stub", _NET, 1.0, 2.0, seed=7)
    batch2 = srv.form_batch()
    assert batch2 is not None, "stale result was cached: re-ask hit the LRU"
    srv.publish_batch(batch2, *srv.execute_batch(batch2))
    resp2 = srv.response(rid2)
    assert resp2.result.selection.latency == 1.0   # new params served
    # and the fresh (post-swap) result IS cached normally
    rid3 = srv.submit("stub", _NET, 1.0, 2.0, seed=7)
    assert srv.response(rid3).cached


def test_swap_before_form_serves_and_caches_new_params():
    """Control: a swap that lands before formation stamps the batch with
    the new generation — its results cache normally (no false stales)."""
    srv = _stub_server()
    rid = srv.submit("stub", _NET, 1.0, 2.0, seed=3)
    srv.swap("stub", ds=None, g_params=5.0)
    batch = srv.form_batch()
    srv.publish_batch(batch, *srv.execute_batch(batch))
    assert srv.response(rid).result.selection.latency == 5.0
    assert srv.stats["stale_cache_skips"] == 0
    rid2 = srv.submit("stub", _NET, 1.0, 2.0, seed=3)
    assert srv.response(rid2).cached


def test_swap_race_under_threads_never_poisons_cache():
    """Barrier-raced swapper vs dispatcher over many rounds: whatever the
    interleaving, a cached entry must always have been computed under the
    generation current at publish time — re-asking any key right after
    wait-free quiescence yields the *current* params' tag."""
    srv = _stub_server()
    lock = threading.Lock()   # the front-end lock role (serializes
                              # form/publish/swap; execute runs outside)
    rounds = 40
    tags = []

    def one_round(i):
        barrier = threading.Barrier(2)

        def dispatcher():
            with lock:
                srv.submit("stub", _NET, 1.0, float(i + 2), seed=i)
                batch = srv.form_batch()
            results, info = srv.execute_batch(batch)   # lock-free window
            barrier.wait()                             # maximize overlap
            with lock:
                srv.publish_batch(batch, results, info)

        def swapper():
            barrier.wait()
            with lock:
                srv.swap("stub", ds=None, g_params=float(i + 1))

        _run_threads([dispatcher, swapper])
        # after both: whatever was cached (if anything) must answer with
        # the CURRENT params tag when re-asked
        with lock:
            rid = srv.submit("stub", _NET, 1.0, float(i + 2), seed=i)
            batch = srv.form_batch()
        if batch is not None:
            results, info = srv.execute_batch(batch)
            with lock:
                srv.publish_batch(batch, results, info)
        tags.append(srv.response(rid).result.selection.latency)

    for i in range(rounds):
        one_round(i)
    # every re-ask saw the post-swap params of its round, never a retired
    # generation's Selection resurrected from the cache
    assert tags == [float(i + 1) for i in range(rounds)]
