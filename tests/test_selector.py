"""Algorithm 2 (design selector) properties."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.encoding import ConfigDim, ConfigSpace
from repro.core.selector import select
from repro.design_models.base import DesignModel


class TableModel(DesignModel):
    """Lookup design model: candidate index i -> (lat[i], pow[i])."""

    name = "table"

    def __init__(self, lat, pw):
        self.lat = np.asarray(lat, np.float64)
        self.pw = np.asarray(pw, np.float64)
        self.space = ConfigSpace(dims=(
            ConfigDim("i", tuple(float(i) for i in range(len(self.lat)))),))
        self.net_space = ConfigSpace(dims=(ConfigDim("n", (0.0, 1.0)),))

    def evaluate(self, net, config):
        i = config[..., 0].astype(int)
        return self.lat[i], self.pw[i]


def run(lat, pw, lo, po):
    model = TableModel(lat, pw)
    cands = np.arange(len(lat), dtype=np.int32)[:, None]
    return select(model, np.array([0]), cands, lo, po)


@given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                min_size=1, max_size=40),
       st.floats(0.2, 8), st.floats(0.2, 8))
@settings(max_examples=60, deadline=None)
def test_selector_finds_satisfying_when_exists(pairs, lo, po):
    lat = [p[0] for p in pairs]
    pw = [p[1] for p in pairs]
    sel = run(lat, pw, lo, po)
    exists = any(l <= lo and p <= po for l, p in pairs)
    if exists:
        # Algorithm 2's scenario rules guarantee a satisfied final pick
        assert sel.satisfied
        assert sel.latency <= lo * 1.01 and sel.power <= po * 1.01


@given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                min_size=1, max_size=40),
       st.floats(0.2, 8), st.floats(0.2, 8))
@settings(max_examples=60, deadline=None)
def test_selector_result_is_a_candidate(pairs, lo, po):
    lat = [p[0] for p in pairs]
    pw = [p[1] for p in pairs]
    sel = run(lat, pw, lo, po)
    assert sel.cfg_idx is not None
    i = int(sel.cfg_idx[0])
    assert np.isclose(lat[i], sel.latency) and np.isclose(pw[i], sel.power)


def test_selector_prefers_dominating_improvement():
    # both satisfied: only a strictly-better-on-both candidate replaces
    sel = run([0.9, 0.8, 0.85], [0.9, 0.8, 0.95], 1.0, 1.0)
    assert sel.latency == 0.8 and sel.power == 0.8


def test_selector_priority_satisfy_first():
    # candidate 0 unsat (lat 2.0), candidate 1 brings latency under LO while
    # staying under PO -> scenario 2 forces the update
    sel = run([2.0, 0.9], [0.5, 0.8], 1.0, 1.0)
    assert sel.satisfied and sel.latency == 0.9


def test_selector_empty_candidates():
    model = TableModel([1.0], [1.0])
    sel = select(model, np.array([0]), np.zeros((0, 1), np.int32), 1.0, 1.0)
    assert not sel.satisfied and sel.n_candidates == 0


def test_improvement_ratio_formula():
    sel = run([0.5], [0.5], 1.0, 1.0)
    # sqrt(1/2 (0.25 + 0.25)) = 0.5
    assert abs(sel.improvement_ratio(1.0, 1.0) - 0.5) < 1e-12
