"""Design-model invariants (property-based).

The paper's models are calibrated against RTL simulation; ours are stated
analytic constants, so the tests check *physics-shaped* invariants rather
than absolute numbers.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.design_models.tpu_mesh import TpuMeshModel


@pytest.fixture(scope="module")
def im2col():
    return Im2colModel()


@pytest.fixture(scope="module")
def dnnw():
    return DnnWeaverModel()


def _sample(model, seed, n=64):
    rng = np.random.default_rng(seed)
    return (model.net_space.sample_indices(rng, n),
            model.space.sample_indices(rng, n))


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_im2col_more_pes_never_slower(seed):
    model = Im2colModel()
    net_idx, cfg_idx = _sample(model, seed)
    lo = cfg_idx.copy()
    hi = cfg_idx.copy()
    lo[:, 0] = 0                       # min PEN
    hi[:, 0] = model.space.dims[0].n - 1  # max PEN
    lat_lo, _ = model.evaluate_indices(net_idx, lo)
    lat_hi, _ = model.evaluate_indices(net_idx, hi)
    ok = np.isfinite(lat_lo) & np.isfinite(lat_hi)
    assert np.all(lat_hi[ok] <= lat_lo[ok] * (1 + 1e-9))


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_im2col_more_sram_more_static_power_when_feasible(seed):
    model = Im2colModel()
    net_idx, cfg_idx = _sample(model, seed)
    lo = cfg_idx.copy(); hi = cfg_idx.copy()
    for d in (3, 4, 5):                # ISS, WSS, OSS
        lo[:, d] = np.minimum(lo[:, d], hi[:, d])
        hi[:, d] = model.space.dims[d].n - 1
    lat_lo, p_lo = model.evaluate_indices(net_idx, lo)
    lat_hi, p_hi = model.evaluate_indices(net_idx, hi)
    # same latency rows (tiling unchanged): bigger SRAM costs static power
    ok = np.isfinite(p_lo) & np.isfinite(p_hi) & np.isclose(lat_lo, lat_hi)
    assert np.all(p_hi[ok] >= p_lo[ok] - 1e-9)


def test_im2col_feasibility_infeasible_tile_is_inf(im2col):
    """A tile bigger than every SRAM must be rejected."""
    net = np.array([[256., 256., 64., 64., 5., 5.]])
    cfg = np.array([[4096., 512., 512., 256., 256., 256.,
                     128., 128., 256., 256., 5., 5.]])
    lat, p = im2col.evaluate(net, cfg)
    assert np.isinf(lat[0]) and np.isinf(p[0])


def test_dnnweaver_derived_tiles_always_fit(dnnw):
    rng = np.random.default_rng(0)
    net_idx = dnnw.net_space.sample_indices(rng, 256)
    cfg_idx = dnnw.space.sample_indices(rng, 256)
    net = dnnw.net_space.values_from_indices(net_idx)
    cfg = dnnw.space.values_from_indices(cfg_idx)
    pen, iss, wss, oss = (cfg[..., i] for i in range(4))
    tic, toc, tow, toh, tkw, tkh = dnnw._derive_tiles(net, iss, wss, oss)
    kw, kh = net[..., 4], net[..., 5]
    assert np.all(tic * tkw * tkh * tow * toh <= iss * (1 + 1e-9))
    assert np.all(toc * tow * toh <= oss * (1 + 1e-9))


def test_bigger_network_never_faster(im2col):
    """Scaling every net dim up cannot reduce latency at a fixed config."""
    rng = np.random.default_rng(3)
    cfg_idx = im2col.space.sample_indices(rng, 128)
    small = np.zeros((128, 6), np.int64)
    big = np.stack([np.full(128, d.n - 1) for d in im2col.net_space.dims], -1)
    lat_s, _ = im2col.evaluate_indices(small, cfg_idx)
    lat_b, _ = im2col.evaluate_indices(big, cfg_idx)
    ok = np.isfinite(lat_s) & np.isfinite(lat_b)
    assert np.all(lat_b[ok] >= lat_s[ok])


# ---------------------------------------------------------------------------
# TPU-mesh model (beyond-paper)
# ---------------------------------------------------------------------------
def test_tpu_mesh_more_chips_not_slower_when_feasible():
    model = TpuMeshModel()
    net = np.array([[24., 2048., 4., 4096., 256., 65536.]])
    base = np.array([[1., 8., 4., 4., 1., 2., 1.]])     # 32 chips
    wide = np.array([[1., 16., 4., 4., 1., 2., 1.]])    # 64 chips
    lat_b, pow_b = model.evaluate(net, base)
    lat_w, pow_w = model.evaluate(net, wide)
    assert lat_w[0] <= lat_b[0] * (1 + 1e-9)


def test_tpu_mesh_infeasible_hbm_is_inf():
    model = TpuMeshModel()
    net = np.array([[64., 7168., 5., 32768., 512., 262144.]])   # ~20B params
    tiny = np.array([[1., 1., 1., 1., 0., 4., 1.]])             # 1 chip
    lat, p = model.evaluate(net, tiny)
    assert np.isinf(lat[0])


def test_tpu_mesh_compression_helps_multipod_collective():
    model = TpuMeshModel()
    net = np.array([[48., 4096., 4., 4096., 512., 131072.]])
    nocomp = np.array([[2., 16., 16., 1., 1., 2., 1.]])
    comp = np.array([[2., 16., 16., 1., 1., 2., 4.]])
    lat_n, _ = model.evaluate(net, nocomp)
    lat_c, _ = model.evaluate(net, comp)
    assert lat_c[0] <= lat_n[0] * (1 + 1e-9)
