"""repro-lint self-tests: every rule proven live by a failing fixture.

Per rule: the bad fixture fires exactly once with the expected code, the
good twin is silent, and inserting ``# lint: disable=<rule>`` above the
reported line silences it.  Plus framework-level coverage: file/def-span
suppressions, --select, JSON output, the exit-code contract, and parse
errors surfacing as findings instead of crashes.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from tools.lint import lint_source, make_rules
from tools.lint.__main__ import main as lint_main

# code -> (path, bad source, good source); path matters for the
# path-scoped rules (GL107 is strict only under serve//checkpoint/)
FIXTURES = {
    "GL101": ("mod.py", """
        import jax

        def f(key):
            a = jax.random.normal(key)
            b = jax.random.uniform(key)
            return a + b
        """, """
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1)
            b = jax.random.uniform(k2)
            return a + b
        """),
    "GL102": ("mod.py", """
        import jax

        def f(seed):
            return jax.random.PRNGKey(seed + 3)
        """, """
        import jax

        def f(seed):
            return jax.random.fold_in(jax.random.PRNGKey(seed), 3)
        """),
    "GL103": ("mod.py", """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) + 1
        """, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return jnp.asarray(x) + 1
        """),
    "GL104": ("mod.py", """
        from jax.sharding import PartitionSpec as P

        SPEC = P("data", "rows")
        """, """
        from jax.sharding import PartitionSpec as P

        SPEC = P(("pod", "data"), "model", None)
        """),
    "GL105": ("mod.py", """
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(state, x):
            return state + x

        def train(state, xs):
            out = step(state, xs)
            return out + state.mean()
        """, """
        import functools
        import jax

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step(state, x):
            return state + x

        def train(state, xs):
            state = step(state, xs)
            return state.mean()
        """),
    "GL106": ("mod.py", """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = []

            def add(self, x):
                with self._lock:
                    self._items.append(x)

            def size(self):
                return len(self._items)
        """, """
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._items = []

            def add(self, x):
                with self._lock:
                    self._items.append(x)

            def size(self):
                with self._lock:
                    return len(self._items)
        """),
    "GL107": ("src/repro/serve/mod.py", """
        def dispatch(g):
            try:
                return g()
            except Exception:
                return None
        """, """
        def dispatch(g):
            try:
                return g()
            except Exception as e:
                return {"error": repr(e)}
        """),
    "GL108": ("mod.py", """
        from jax.experimental import pallas as pl

        def run(kern, x):
            b, d = x.shape
            return pl.pallas_call(
                kern,
                in_specs=[pl.BlockSpec((8, d), lambda i: (0, 0))],
            )(x)
        """, """
        from jax.experimental import pallas as pl

        def run(kern, x):
            bd = _pick(128, x.shape[1])
            return pl.pallas_call(
                kern,
                in_specs=[pl.BlockSpec((8, bd), lambda i: (0, 0))],
            )(x)
        """),
    "GL109": ("mod.py", """
        import jax

        def f(g, x):
            return jax.jit(g)(x)
        """, """
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def make(g):
            return jax.jit(g)

        def f(g, x):
            return make(g)(x)
        """),
    "GL110": ("mod.py", """
        def violation(lat, pw, lo, po):
            return max(lat - lo, 0.0) + max(pw - po, 0.0)
        """, """
        import numpy as np

        def violation(lat, pw, lo, po):
            if not (np.isfinite(lat) and np.isfinite(pw)):
                return float("inf")
            return max(lat - lo, 0.0) + max(pw - po, 0.0)
        """),
    "GL111": ("mod.py", """
        def refresh(fe, ds, params):
            return fe.server.swap("m", ds, params)
        """, """
        def refresh(fe, ds, params):
            return fe.swap("m", ds, params)
        """),
    "GL112": ("mod.py", """
        import numpy as np

        def explore_batch(probs, cap):
            keep, total = _masks(probs, cap)
            counts_host = np.asarray(total)
            c_pad = _bucket(counts_host.max())
            return _unravel(keep, total, c_pad)
        """, """
        def explore_batch(probs, cap):
            keep, total = _masks(probs, cap)
            return _select_tiled(keep, total, cap)
        """),
}

RULE_NAMES = {r.code: r.name for r in make_rules()}


def _lint(code, src, path):
    return lint_source(textwrap.dedent(src), path=path)


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_bad_fixture_fires_exactly_once(code):
    path, bad, _good = FIXTURES[code]
    findings = _lint(code, bad, path)
    assert len(findings) == 1, findings
    assert findings[0].code == code
    assert findings[0].rule == RULE_NAMES[code]


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_good_fixture_is_silent(code):
    path, _bad, good = FIXTURES[code]
    assert _lint(code, good, path) == []


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_line_suppression_silences(code):
    path, bad, _good = FIXTURES[code]
    src = textwrap.dedent(bad)
    (finding,) = lint_source(src, path=path)
    lines = src.splitlines()
    lines.insert(finding.line - 1,
                 f"# lint: disable={RULE_NAMES[code]}")
    assert lint_source("\n".join(lines), path=path) == []


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_file_suppression_silences(code):
    path, bad, _good = FIXTURES[code]
    src = (f"# lint: disable-file={RULE_NAMES[code]}\n"
           + textwrap.dedent(bad))
    assert lint_source(src, path=path) == []


# ---------------------------------------------------------------------------
# extra rule-behavior cases beyond the canonical pairs
# ---------------------------------------------------------------------------
def test_prng_loop_reuse_fires():
    src = textwrap.dedent("""
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key))
            return out
        """)
    (f,) = lint_source(src, path="mod.py")
    assert f.code == "GL101" and "loop" in f.message


def test_prng_fold_in_per_iteration_is_clean():
    src = textwrap.dedent("""
        import jax

        def f(key, n):
            out = []
            for i in range(n):
                k = jax.random.fold_in(key, i)
                out.append(jax.random.normal(k))
            return out
        """)
    assert lint_source(src, path="mod.py") == []


def test_seed_mask_is_sanctioned():
    src = textwrap.dedent("""
        import jax

        def f(seed, i):
            return jax.random.PRNGKey((seed * 1000003 + i) & 0xFFFFFFFF)
        """)
    assert lint_source(src, path="mod.py") == []


def test_host_sync_reachable_through_helper():
    src = textwrap.dedent("""
        import jax

        def helper(x):
            return x.item()

        def outer(xs):
            def body(c, x):
                return c + helper(x), None
            return jax.lax.scan(body, 0.0, xs)
        """)
    # helper is reached from the scanned body; body itself is nested (not
    # module-visible) but helper is flagged via the jit-taker root scan
    findings = lint_source(src, path="mod.py")
    assert any(f.code == "GL103" and ".item()" in f.message
               for f in findings)


def test_host_sync_marker_sanctions():
    src = textwrap.dedent("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            # deliberate host fallback  # lint: host-sync-ok
            return np.asarray(x) + 1
        """)
    assert lint_source(src, path="mod.py") == []


def test_pspec_empty_tuple_and_duplicate_axis():
    src = textwrap.dedent("""
        from jax.sharding import PartitionSpec

        A = PartitionSpec((), "data")
        B = PartitionSpec("data", "data")
        """)
    codes = [(f.code, f.line) for f in lint_source(src, path="mod.py")]
    assert len(codes) == 2 and all(c == "GL104" for c, _ in codes)


def test_aot_lower_compile_is_exempt():
    src = textwrap.dedent("""
        import jax

        def compile_ahead(g, x):
            return jax.jit(g).lower(x).compile()
        """)
    assert lint_source(src, path="mod.py") == []


def test_bare_except_fires_everywhere():
    src = textwrap.dedent("""
        def f(g):
            try:
                return g()
            except:
                return None
        """)
    (f,) = lint_source(src, path="mod.py")
    assert f.code == "GL107"


def test_broad_unbound_except_ok_outside_strict_paths():
    path, bad, _good = FIXTURES["GL107"]
    assert lint_source(textwrap.dedent(bad), path="src/repro/launch/x.py") \
        == []


def test_reraise_cleanup_is_exempt_in_strict_paths():
    src = textwrap.dedent("""
        def save(tmp):
            try:
                publish(tmp)
            except BaseException:
                cleanup(tmp)
                raise
        """)
    assert lint_source(src, path="src/repro/checkpoint/mod.py") == []


def test_swap_under_own_lock_is_clean():
    """The ServeFrontend.swap shape itself: `self.server.swap` under
    `with self._lock:` in a lock-owning class is the sanctioned wrapper,
    not a bypass."""
    src = textwrap.dedent("""
        import threading

        class Frontend:
            def __init__(self, server):
                self._lock = threading.RLock()
                self.server = server

            def swap(self, name, ds, params):
                with self._lock:
                    return self.server.swap(name, ds, params)
        """)
    assert lint_source(src, path="mod.py") == []


def test_swap_lock_bypass_fires_in_methods_too():
    """A lock-owning class calling `.server.swap` while NOT holding its
    lock is still a bypass."""
    src = textwrap.dedent("""
        import threading

        class Loop:
            def __init__(self, fe):
                self._lock = threading.Lock()
                self.fe = fe

            def refresh(self, ds, params):
                return self.fe.server.swap("m", ds, params)
        """)
    findings = lint_source(src, path="mod.py")
    assert [f.code for f in findings] == ["GL111"]


def test_dispatch_sync_reachable_through_helper():
    """The explorer.py:249 bug class: the host read hid inside a helper
    the dispatch entry point called by simple name."""
    src = textwrap.dedent("""
        import numpy as np

        def _pick_pad(total):
            return int(np.asarray(total).max())

        def execute_batch(batch, run):
            out, total = run(batch)
            return out[: _pick_pad(total)]
        """)
    findings = lint_source(src, path="mod.py")
    assert [f.code for f in findings] == ["GL112"]
    # int(np.asarray(...)) on one line fires once, not per detector
    assert "np" in findings[0].message or "device->host" in findings[0].message


def test_dispatch_sync_marker_sanctions():
    src = textwrap.dedent("""
        import numpy as np

        def explore_batch(tasks, run):
            sels = run(tasks)
            # results consumed on host  # lint: dispatch-sync-ok
            return np.asarray(sels)
        """)
    assert lint_source(src, path="mod.py") == []


def test_dispatch_sync_ignores_non_dispatch_functions():
    """Host tails outside the dispatch roots (e.g. the float64 re-score
    in selections_from_winners) are deliberately out of scope."""
    src = textwrap.dedent("""
        import numpy as np

        def selections_from_winners(chosen, win):
            return np.asarray(chosen), np.asarray(win)
        """)
    assert lint_source(src, path="mod.py") == []


def test_def_span_suppression():
    path, bad, _good = FIXTURES["GL106"]
    src = textwrap.dedent(bad).replace(
        "    def size(self):",
        "    # caller holds the lock by contract\n"
        "    # lint: disable=lock-discipline\n"
        "    def size(self):")
    assert lint_source(src, path=path) == []


# ---------------------------------------------------------------------------
# framework: selection, output, exit codes
# ---------------------------------------------------------------------------
def test_select_filters_rules():
    path, bad, _good = FIXTURES["GL101"]
    assert _lint("GL101", bad, path) != []
    assert lint_source(textwrap.dedent(bad), path=path,
                       select=["GL104"]) == []
    assert lint_source(textwrap.dedent(bad), path=path,
                       select=["prng-key-reuse"]) != []


def test_parse_error_is_a_finding():
    (f,) = lint_source("def broken(:\n", path="mod.py")
    assert f.code == "GL000" and f.rule == "parse-error"


def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(FIXTURES["GL101"][1]))
    good = tmp_path / "good.py"
    good.write_text(textwrap.dedent(FIXTURES["GL101"][2]))

    assert lint_main([str(good)]) == 0
    assert lint_main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "GL101" in out and "prng-key-reuse" in out

    assert lint_main(["--json", str(bad)]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == 1
    assert payload["findings"][0]["code"] == "GL101"

    assert lint_main([]) == 2                      # no paths
    assert lint_main(["--select", "nope", str(good)]) == 2
    assert lint_main(["--list-rules"]) == 0
    assert len(capsys.readouterr().out.strip().splitlines()) \
        == len(make_rules())


def test_repo_is_clean_at_head():
    """The gate CI enforces: src/ and benchmarks/ lint clean."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", "src", "benchmarks"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_typed_seams_pass_mypy():
    """mypy.ini holds the public seams (dse_api, request, frontend) to
    full annotations; skipped where mypy isn't installed (it is in CI)."""
    pytest.importorskip("mypy")
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file", "mypy.ini"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
