"""ConfigSpace encoding properties (hypothesis)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal CI image — seeded-random fallback
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.encoding import ConfigDim, ConfigSpace, Normalizer


def space_strategy():
    dim = st.builds(
        lambda n, c: ConfigDim(name=f"d{n}", choices=tuple(sorted(set(c)))),
        st.integers(0, 99),
        st.lists(st.floats(1.0, 1e6, allow_nan=False), min_size=2, max_size=8,
                 unique=True),
    )
    return st.builds(lambda ds: ConfigSpace(dims=tuple(ds)),
                     st.lists(dim, min_size=1, max_size=6))


@given(space_strategy(), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_onehot_roundtrip(space, seed):
    rng = np.random.default_rng(seed)
    idx = space.sample_indices(rng, 16)
    oh = space.onehot_from_indices(idx)
    assert oh.shape == (16, space.onehot_width)
    np.testing.assert_array_equal(space.indices_from_onehot(oh), idx)
    # per-group rows sum to 1 exactly
    off = 0
    for d in space.dims:
        np.testing.assert_allclose(oh[:, off:off + d.n].sum(-1), 1.0)
        off += d.n


@given(space_strategy(), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_value_roundtrip(space, seed):
    rng = np.random.default_rng(seed)
    idx = space.sample_indices(rng, 8)
    vals = space.values_from_indices(idx)
    np.testing.assert_array_equal(space.indices_from_values(vals), idx)


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=4,
                max_size=64))
@settings(max_examples=40, deadline=None)
def test_normalizer_inverse(xs):
    x = np.asarray(xs)[:, None]
    nm = Normalizer.fit(x, center=True)
    np.testing.assert_allclose(nm.inverse(nm(x)), x, rtol=1e-9, atol=1e-6)


def test_soft_onehot_argmax():
    space = ConfigSpace(dims=(ConfigDim("a", (1., 2., 4.)),
                              ConfigDim("b", (8., 16.))))
    soft = np.array([[0.1, 0.7, 0.2, 0.4, 0.6]])
    np.testing.assert_array_equal(space.indices_from_onehot(soft), [[1, 1]])
