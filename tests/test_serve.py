"""DSE serving parity — the `DSEServer` contract.

N interleaved single submissions must be Selection-identical to ONE direct
`explore_tasks` call on the same tasks (micro-batching, pow2 padding, and
queue order are invisible to correctness), including zero-feasible tasks;
a warm (cache-hit) pass returns the same results without dispatching;
identical in-flight requests coalesce into one dispatched row; and a
params hot-swap through `DSEServer.swap` serves the new params without
recompiling the generator forward.
"""
import jax
import numpy as np
import pytest

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig, _cached_fwd
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel
from repro.serve import DSEServer, ServeConfig

MODELS = {m.name: m for m in (DnnWeaverModel, Im2colModel)}


@pytest.fixture(scope="module")
def models():
    return {name: cls() for name, cls in MODELS.items()}


def _attached(model, tiny_gan_cfg, small_dataset, seed=3, ds_model=None):
    """Random-init generator: serving parity does not depend on training
    quality (same rationale as test_explore_batch)."""
    cfg = tiny_gan_cfg(model)
    g = GANDSE(model, cfg,
               ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(ds_model or model, n=256)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(seed), cfg, model.space))
    return g


def _assert_selection_equal(tag, i, sa, sb):
    assert sa.n_candidates == sb.n_candidates, (tag, i)
    assert (sa.cfg_idx is None) == (sb.cfg_idx is None), (tag, i)
    if sa.cfg_idx is not None:
        np.testing.assert_array_equal(sa.cfg_idx, sb.cfg_idx,
                                      err_msg=f"{tag}[{i}]")
    assert sa.latency == sb.latency and sa.power == sb.power, (tag, i)
    assert sa.satisfied == sb.satisfied, (tag, i)


def _submit_all(srv, model, tasks, seed0, order):
    """Single submissions in an arbitrary interleaving; rid -> task row."""
    rid_to_row = {}
    for i in order:
        rid = srv.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                         tasks.pow_obj[i], seed=seed0 + i)
        rid_to_row[rid] = i
    return rid_to_row


def test_server_parity_with_direct_batch(models, tiny_gan_cfg, small_dataset):
    """Shuffled single submissions through small micro-batches (4+2, the
    tail pow2-padded) == one direct explore_tasks call, row by row."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(g)
    tasks = generate_tasks(model, 6, seed=2)
    direct = g.explore_tasks(tasks, seed=7)

    order = [3, 0, 5, 1, 4, 2]              # arrival != task order
    rid_to_row = _submit_all(srv, model, tasks, 7, order)
    responses = srv.drain()
    assert len(responses) == 6
    assert srv.stats["batches"] == 2        # 4 + 2 (pow2 buckets)
    assert srv.stats["padded_rows"] == 0    # 4 and 2 are already pow2
    for r in responses:
        i = rid_to_row[r.rid]
        _assert_selection_equal("parity", i, r.result.selection,
                                direct[i].selection)

    # ragged arrival: 3 requests coalesce into one micro-batch padded to
    # its pow2 bucket (4); the padding row is discarded, rows unchanged
    srv2 = DSEServer(ServeConfig(max_batch=4, cache_capacity=0))
    srv2.register(g)
    rid_to_row = _submit_all(srv2, model, tasks, 7, [2, 0, 1])
    for r in srv2.drain():
        i = rid_to_row[r.rid]
        _assert_selection_equal("padded", i, r.result.selection,
                                direct[i].selection)
    assert srv2.stats["padded_rows"] == 1   # 3 real rows -> pow2 bucket 4


def test_server_parity_zero_feasible(models, tiny_gan_cfg, small_dataset):
    """Tasks whose every candidate is infeasible serve cleanly (no config,
    not satisfied) and still match the direct batch."""
    from test_explore_batch import _InfeasibleModel

    model = _InfeasibleModel()
    g = _attached(model, tiny_gan_cfg, small_dataset,
                  ds_model=models["dnnweaver"])
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(g)
    tasks = generate_tasks(models["dnnweaver"], 6, seed=2)
    direct = g.explore_tasks(tasks, seed=7)
    rid_to_row = _submit_all(srv, model, tasks, 7, list(range(6)))
    for r in srv.drain():
        i = rid_to_row[r.rid]
        _assert_selection_equal("zero_feasible", i, r.result.selection,
                                direct[i].selection)
        assert r.result.selection.cfg_idx is None
        assert not r.result.satisfied


def test_server_warm_pass_hits_cache(models, tiny_gan_cfg, small_dataset):
    """Cold pass dispatches; an identical warm pass answers entirely from
    the LRU cache with the same Selections."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(g)
    tasks = generate_tasks(model, 6, seed=2)

    rid_to_row = _submit_all(srv, model, tasks, 7, range(6))
    cold = {rid_to_row[r.rid]: r for r in srv.drain()}
    assert all(r.source == "dispatch" for r in cold.values())
    batches_after_cold = srv.stats["batches"]

    rid_to_row = _submit_all(srv, model, tasks, 7, range(6))
    warm = {rid_to_row[r.rid]: r for r in srv.drain()}
    assert srv.stats["batches"] == batches_after_cold   # nothing dispatched
    for i in range(6):
        assert warm[i].cached and warm[i].source == "cache"
        _assert_selection_equal("warm", i, warm[i].result.selection,
                                cold[i].result.selection)
    # a different seed is a different key: must miss and dispatch
    rid = srv.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                     tasks.pow_obj[0], seed=99)
    (resp,) = srv.drain()
    assert resp.rid == rid and resp.source == "dispatch"


def test_server_coalesces_identical_inflight(models, tiny_gan_cfg,
                                             small_dataset):
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(g)
    tasks = generate_tasks(model, 2, seed=2)
    args = (model.name, tasks.net_idx[0], tasks.lat_obj[0], tasks.pow_obj[0])
    r1 = srv.submit(*args, seed=7)
    r2 = srv.submit(*args, seed=7)          # identical, still queued
    r3 = srv.submit(model.name, tasks.net_idx[1], tasks.lat_obj[1],
                    tasks.pow_obj[1], seed=8)
    responses = {r.rid: r for r in srv.drain()}
    assert srv.stats["dispatched_rows"] == 2            # not 3
    assert srv.stats["coalesced"] == 1
    assert responses[r2].source == "coalesced"
    _assert_selection_equal("coalesce", 0, responses[r1].result.selection,
                            responses[r2].result.selection)
    assert responses[r3].source == "dispatch"


def test_multi_model_registry_round_robin(models, tiny_gan_cfg,
                                          small_dataset):
    """One server hosts one engine per design model; interleaved
    submissions for both models each match their own direct batch."""
    g1 = _attached(models["dnnweaver"], tiny_gan_cfg, small_dataset)
    g2 = _attached(models["im2col"], tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(g1)
    srv.register(g2)
    t1 = generate_tasks(models["dnnweaver"], 4, seed=2)
    t2 = generate_tasks(models["im2col"], 4, seed=2)
    direct1 = g1.explore_tasks(t1, seed=7)
    direct2 = g2.explore_tasks(t2, seed=7)

    rids = {}
    for i in range(4):                      # strict interleave
        rids[srv.submit("dnnweaver", t1.net_idx[i], t1.lat_obj[i],
                        t1.pow_obj[i], seed=7 + i)] = ("dnnweaver", i)
        rids[srv.submit("im2col", t2.net_idx[i], t2.lat_obj[i],
                        t2.pow_obj[i], seed=7 + i)] = ("im2col", i)
    responses = srv.drain()
    assert len(responses) == 8
    for r in responses:
        name, i = rids[r.rid]
        want = (direct1 if name == "dnnweaver" else direct2)[i]
        assert r.model_name == name
        _assert_selection_equal(name, i, r.result.selection,
                                want.selection)


def test_dispatch_failure_loses_no_requests(models, tiny_gan_cfg,
                                            small_dataset):
    """Error path: an engine exception mid-dispatch re-queues the popped
    requests (followers stay attached); the failure surfaces to the caller
    and a retry answers everything."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)

    class Flaky:
        """Engine wrapper that fails its first dispatch."""
        def __init__(self, inner):
            self._inner, self.model, self.calls = inner, inner.model, 0

        def explore_tasks(self, tasks, seed=0):
            self.calls += 1
            if self.calls == 1:
                raise RuntimeError("transient engine failure")
            return self._inner.explore_tasks(tasks, seed=seed)

    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(Flaky(g))
    tasks = generate_tasks(model, 2, seed=2)
    rids = _submit_all(srv, model, tasks, 7, range(2))
    dup = srv.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                     tasks.pow_obj[0], seed=7)            # coalesced follower
    with pytest.raises(RuntimeError, match="transient"):
        srv.drain()
    assert srv.batcher.pending() == 2                     # nothing lost
    responses = {r.rid: r for r in srv.drain()}           # retry succeeds
    assert set(responses) == set(rids) | {dup}
    direct = g.explore_tasks(tasks, seed=7)
    for rid, i in rids.items():
        _assert_selection_equal("retry", i, responses[rid].result.selection,
                                direct[i].selection)
    assert responses[dup].source == "coalesced"


def test_poison_request_cannot_wedge_the_queue(models, tiny_gan_cfg,
                                               small_dataset):
    """A deterministically-failing dispatch must not starve the model's
    queue: past the retry cap the carrying batch's requests get FAILED
    responses (with the error) and later submissions are served."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)

    class PoisonOnSeed:
        """Engine wrapper that always fails on batches carrying seed 666."""
        def __init__(self, inner):
            self._inner, self.model = inner, inner.model

        def explore_tasks(self, tasks, seed=0):
            if np.any(np.asarray(seed) == 666):
                raise RuntimeError("poison request")
            return self._inner.explore_tasks(tasks, seed=seed)

    srv = DSEServer(ServeConfig(max_batch=8))
    srv.register(PoisonOnSeed(g))
    tasks = generate_tasks(model, 3, seed=2)
    bad = srv.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                     tasks.pow_obj[0], seed=666)
    other = srv.submit(model.name, tasks.net_idx[1], tasks.lat_obj[1],
                       tasks.pow_obj[1], seed=7)
    for _ in range(2):                       # attempts 1 and 2 both raise
        with pytest.raises(RuntimeError, match="poison"):
            srv.drain()
    assert srv.batcher.pending() == 0        # not requeued past the cap
    responses = {r.rid: r for r in srv.drain()}
    assert responses[bad].source == "failed" and not responses[bad].ok
    assert "poison" in responses[bad].error
    assert responses[other].source == "failed"   # collateral of its batch
    # the queue is unwedged: a fresh request is served normally
    rid = srv.submit(model.name, tasks.net_idx[2], tasks.lat_obj[2],
                     tasks.pow_obj[2], seed=8)
    (resp,) = srv.drain()
    assert resp.rid == rid and resp.ok and resp.source == "dispatch"
    assert srv.stats["failed"] == 2


def test_submit_rejects_malformed_net_idx(models, tiny_gan_cfg,
                                          small_dataset):
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig())
    srv.register(g)
    with pytest.raises(ValueError, match="dims"):
        srv.submit(model.name, np.zeros(99, np.int64), 1e-3, 2.0)
    n_dims = model.net_space.n_dims
    with pytest.raises(ValueError, match="out of range"):
        srv.submit(model.name, np.full(n_dims, 10**6), 1e-3, 2.0)
    with pytest.raises(ValueError, match="out of range"):
        # a negative index would wrap silently and cache the wrong network
        srv.submit(model.name, np.full(n_dims, -1), 1e-3, 2.0)
    assert srv.batcher.pending() == 0        # nothing admitted


def test_submit_copies_net_idx(models, tiny_gan_cfg, small_dataset):
    """The admitted request must not alias the caller's buffer: mutating
    it after submit() must not change what is explored or cached."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(g)
    tasks = generate_tasks(model, 2, seed=2)
    buf = np.array(tasks.net_idx[0], np.int64)   # int64: asarray would alias
    rid = srv.submit(model.name, buf, tasks.lat_obj[0], tasks.pow_obj[0],
                     seed=7)
    buf[:] = 0                                   # caller reuses the buffer
    (resp,) = srv.drain()
    direct = g.explore(tasks.net_idx[0], tasks.lat_obj[0], tasks.pow_obj[0],
                       seed=7)
    _assert_selection_equal("copy", 0, resp.result.selection,
                            direct.selection)
    # and the cache key matches the ORIGINAL values, not the mutated buffer
    warm = srv.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                      tasks.pow_obj[0], seed=7)
    (hit,) = srv.drain()
    assert hit.rid == warm and hit.cached


def test_response_retention_is_bounded(models, tiny_gan_cfg, small_dataset):
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=4, cache_capacity=0,
                                response_retention=2))
    srv.register(g)
    tasks = generate_tasks(model, 4, seed=2)
    rid_to_row = _submit_all(srv, model, tasks, 7, range(4))
    responses = srv.drain()
    # both the rid lookup map AND the drain outbox hold only the newest
    # `response_retention` entries (a polling loop that never drains must
    # not accumulate responses forever); size retention for the expected
    # per-drain volume in drain-based loops
    assert [r.rid for r in responses] == sorted(rid_to_row)[-2:]
    assert len(srv._responses) == 2                       # oldest evicted
    assert all(srv.response(r) is not None
               for r in sorted(rid_to_row)[-2:])
    assert srv.stats["dispatched_rows"] == 4              # work still done


def test_hot_swap_refreshes_params_without_recompile(models, tiny_gan_cfg,
                                                     small_dataset):
    """`DSEServer.swap` serves the new params (cache invalidated, results
    match a fresh engine built on those params) and never recompiles: the
    compiled G forward is cached on (space, gan_cfg) and the swapped
    Explorer reuses the same function with no new trace."""
    model = models["dnnweaver"]
    cfg = tiny_gan_cfg(model)
    ds = small_dataset(model, n=256)
    params_a = G.init_generator(jax.random.PRNGKey(3), cfg, model.space)
    params_b = G.init_generator(jax.random.PRNGKey(4), cfg, model.space)

    g = GANDSE(model, cfg,
               ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    g.attach(ds, params_a)
    srv = DSEServer(ServeConfig(max_batch=4))
    srv.register(g)
    tasks = generate_tasks(model, 4, seed=2)
    rid_to_row = _submit_all(srv, model, tasks, 7, range(4))
    cold = {rid_to_row[r.rid]: r for r in srv.drain()}

    fwd_before = g._explorer._fwd
    info_before = _cached_fwd.cache_info()
    cache_size = getattr(fwd_before, "_cache_size", None)
    traces_before = cache_size() if cache_size else None

    invalidated = srv.swap(model.name, ds, params_b)
    assert invalidated == 4                  # stale results dropped

    rid_to_row = _submit_all(srv, model, tasks, 7, range(4))
    swapped = {rid_to_row[r.rid]: r for r in srv.drain()}
    assert all(r.source == "dispatch" for r in swapped.values())

    # no recompilation: same compiled forward object, no new lru entry,
    # and (when the jit cache is introspectable) no new traced program
    assert g._explorer._fwd is fwd_before
    info_after = _cached_fwd.cache_info()
    assert info_after.misses == info_before.misses
    if traces_before is not None:
        assert cache_size() == traces_before

    # the swap actually took: results come from params_b
    g_b = GANDSE(model, cfg,
                 ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    g_b.attach(ds, params_b)
    direct_b = g_b.explore_tasks(tasks, seed=7)
    changed = 0
    for i in range(4):
        _assert_selection_equal("swap", i, swapped[i].result.selection,
                                direct_b[i].selection)
        sa, sb = cold[i].result.selection, swapped[i].result.selection
        changed += int(sa.cfg_idx is None or sb.cfg_idx is None
                       or not np.array_equal(sa.cfg_idx, sb.cfg_idx)
                       or sa.n_candidates != sb.n_candidates)
    assert changed > 0, "different params produced identical selections"


# ---------------------------------------------------------------------------
# robustness: backoff, admission control, deadlines, degraded fallback
# ---------------------------------------------------------------------------
def test_retry_backoff_window_blocks_then_allows(models, tiny_gan_cfg,
                                                 small_dataset):
    """A failed dispatch arms a jittered-exponential backoff window: step()
    refuses to re-hammer the engine inside it (visible in summary()) and
    dispatches normally once it expires; drain() sleeps it out."""
    import time

    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)

    class FailsOnce:
        def __init__(self, inner):
            self._inner, self.model, self.calls = inner, inner.model, 0

        def explore_tasks(self, tasks, seed=0):
            self.calls += 1
            if self.calls == 1:
                raise RuntimeError("transient engine failure")
            return self._inner.explore_tasks(tasks, seed=seed)

    srv = DSEServer(ServeConfig(max_batch=8, retry_backoff_base=0.25,
                                retry_jitter=0.0))
    srv.register(FailsOnce(g))
    tasks = generate_tasks(model, 2, seed=2)
    rids = _submit_all(srv, model, tasks, 7, range(2))
    with pytest.raises(RuntimeError, match="transient"):
        srv.step()
    # inside the window: work is pending but step() must not dispatch
    assert srv.batcher.pending() == 2
    assert srv.step() == 0
    backoff = srv.summary()["backoff"]
    assert model.name in backoff and 0 < backoff[model.name] <= 0.25
    assert srv.summary()["inflight_attempts"] == {r: 1 for r in rids}
    time.sleep(0.26)
    assert srv.step() == 2                   # window expired: retry served
    assert srv.stats["dispatch_attempts"] == 2
    assert srv.stats["retried"] == 2
    assert srv.summary()["backoff"] == {}    # cleared by the success


def test_queue_bound_rejects_at_the_door(models, tiny_gan_cfg,
                                         small_dataset):
    """Admission control: submissions past ServeConfig.max_queue get an
    immediate REJECTED response with a retry-after hint instead of
    buffering without bound; admitted work is unaffected."""
    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=8, max_queue=2, cache_capacity=0))
    srv.register(g)
    tasks = generate_tasks(model, 4, seed=2)
    rid_to_row = _submit_all(srv, model, tasks, 7, range(4))
    assert srv.batcher.pending() == 2        # only the first two admitted
    shed = [srv.response(r) for r, i in rid_to_row.items() if i >= 2]
    assert all(r is not None and r.rejected for r in shed)
    assert all("queue full" in r.error for r in shed)
    assert all(r.retry_after and 0 < r.retry_after <= 60 for r in shed)
    assert srv.stats["rejected"] == srv.stats["rejected_queue"] == 2
    direct = g.explore_tasks(tasks, seed=7)
    served = {rid_to_row[r.rid]: r for r in srv.drain() if r.ok}
    assert sorted(served) == [0, 1]
    for i, r in served.items():
        _assert_selection_equal("bounded", i, r.result.selection,
                                direct[i].selection)


def test_deadline_sheds_before_dispatch(models, tiny_gan_cfg,
                                        small_dataset):
    """Per-request deadlines: an already-expired submit is rejected at
    admission; a queued request whose deadline passes is shed by the next
    step() — REJECTED with a hint, never dispatched."""
    import time

    from repro.serve.server import _now

    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    srv = DSEServer(ServeConfig(max_batch=8, cache_capacity=0))
    srv.register(g)
    tasks = generate_tasks(model, 3, seed=2)
    dead = srv.submit(model.name, tasks.net_idx[0], tasks.lat_obj[0],
                      tasks.pow_obj[0], seed=7, deadline=_now() - 1.0)
    resp = srv.response(dead)
    assert resp.rejected and "at admission" in resp.error
    soon = srv.submit(model.name, tasks.net_idx[1], tasks.lat_obj[1],
                      tasks.pow_obj[1], seed=8, deadline=_now() + 0.02)
    ok = srv.submit(model.name, tasks.net_idx[2], tasks.lat_obj[2],
                    tasks.pow_obj[2], seed=9)
    time.sleep(0.03)                         # `soon` expires while queued
    dispatched = srv.stats["batches"]
    responses = {r.rid: r for r in srv.drain()}
    assert responses[soon].rejected
    assert "before dispatch" in responses[soon].error
    assert responses[ok].ok and responses[ok].source == "dispatch"
    assert srv.stats["rejected_deadline"] == 2
    assert srv.stats["dispatched_rows"] == 1   # the expired row never ran
    assert srv.stats["batches"] == dispatched + 1


def test_sync_degraded_fallback_and_recovery(models, tiny_gan_cfg,
                                             small_dataset):
    """Sync pump under a device-route fault burst: consecutive failures
    flip the model onto the sequential host-oracle route (responses flag
    degraded=True, Selections unchanged by the parity contract), and a
    later probe restores the device route."""
    from repro.serve import FaultPlan, FaultyEngine, InjectedFault

    model = models["dnnweaver"]
    g = _attached(model, tiny_gan_cfg, small_dataset)
    faulty = FaultyEngine(g, FaultPlan(burst_start=0, burst_len=2,
                                       device_route_only=True))
    srv = DSEServer(ServeConfig(
        max_batch=4, cache_capacity=0, max_dispatch_attempts=10,
        retry_backoff_base=0.001, retry_jitter=0.0,
        degrade_after=2, degrade_probe_after=1))
    srv.register(faulty)
    tasks = generate_tasks(model, 6, seed=2)
    direct = g.explore_tasks(tasks, seed=7)
    rid_to_row = _submit_all(srv, model, tasks, 7, range(6))
    responses = {}
    for _ in range(50):
        try:
            responses.update({r.rid: r for r in srv.drain()})
        except InjectedFault:
            continue
        break
    responses.update({r.rid: r for r in srv.drain()})
    assert len(responses) == 6
    assert all(r.ok for r in responses.values())
    for rid, i in rid_to_row.items():
        _assert_selection_equal("degraded", i,
                                responses[rid].result.selection,
                                direct[i].selection)
    assert faulty.injected_errors == 2
    assert srv.stats["degraded_entered"] == 1
    assert srv.stats["degraded_batches"] >= 1
    assert srv.stats["degraded_recovered"] == 1
    assert srv.stats["failed"] == 0
    assert any(r.degraded for r in responses.values())
    assert not srv.summary()["degraded"]     # device route healed
