"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs; plus a decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import base as MB
from repro.train import step as TS

ARCHS = configs.list_archs()

# Tier-1 exercises one dense and one MoE architecture end-to-end; the full
# 10-arch matrix (~2 min of CPU jit compiles) runs under `-m slow`.  The
# exotic numerics (ssm/xlstm/moe internals) are covered directly by
# test_substrate.py either way.
CORE_ARCHS = {"stablelm_1_6b", "mixtral_8x7b"}
ARCH_PARAMS = [a if a in CORE_ARCHS else pytest.param(a, marks=pytest.mark.slow)
               for a in ARCHS]


def _inputs(m, b=2, s=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(rng, (b, s), 0, m.vocab),
        "labels": jax.random.randint(rng, (b, s), 0, m.vocab),
    }
    if m.family == "vlm":
        batch["positions"] = jnp.broadcast_to(jnp.arange(s)[None, None],
                                              (3, b, s))
    if m.enc_segments is not None:
        batch["frames"] = jax.random.normal(rng, (b, 24, m.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_no_nans(arch):
    m = configs.get_reduced(arch)
    params = MB.init_params(jax.random.PRNGKey(0), m)
    batch = _inputs(m)
    enc_out = (MB.encode(params, m, batch["frames"])
               if m.enc_segments is not None else None)
    logits = MB.forward(params, m, batch["tokens"],
                        positions=batch.get("positions"), enc_out=enc_out)
    assert logits.shape == (*batch["tokens"].shape, m.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_reduces_loss(arch):
    m = configs.get_reduced(arch)
    params = MB.init_params(jax.random.PRNGKey(0), m)
    step, optim = TS.make_train_step(m, lr=3e-3, remat=False)
    step = jax.jit(step)
    opt = optim.init(params)
    batch = _inputs(m)
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]      # same batch: loss must fall


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_step_no_nans(arch):
    m = configs.get_reduced(arch)
    params = MB.init_params(jax.random.PRNGKey(0), m)
    b = 2
    enc_out = None
    if m.enc_segments is not None:
        frames = jax.random.normal(jax.random.PRNGKey(1), (b, 24, m.d_model)) * 0.1
        enc_out = MB.encode(params, m, frames)
    states = MB.init_decode_state(params, m, b, cache_len=64)
    tok = jnp.zeros((b, 1), jnp.int32)
    for t in range(4):
        logits, states = MB.decode_step(params, m, tok, jnp.int32(t), states,
                                        enc_out=enc_out)
        tok = jnp.argmax(logits, -1)
    assert logits.shape == (b, 1, m.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.slow   # eager token-by-token loop; decode_step_no_nans covers tier-1
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma3-1b", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == teacher-forced forward logits."""
    m = configs.get_reduced(arch)
    params = MB.init_params(jax.random.PRNGKey(0), m)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, m.vocab)
    full = MB.forward(params, m, toks)
    states = MB.init_decode_state(params, m, b, cache_len=64)
    outs = []
    for t in range(s):
        logits, states = MB.decode_step(params, m, toks[:, t:t + 1],
                                        jnp.int32(t), states)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    import numpy as np
    expect = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for name, (L, d, h, kv, dff, vocab) in expect.items():
        m = configs.get_arch(name)
        assert m.n_layers == L, name
        assert m.d_model == d, name
        assert m.vocab == vocab, name
        spec = m.segments[0].pattern[0]
        assert spec.cfg.n_heads == h, name
        assert spec.cfg.n_kv == kv, name
        assert spec.cfg.d_ff == dff, name
    # MoE expert counts
    assert configs.get_arch("mixtral-8x7b").segments[0].pattern[0].cfg.n_experts == 8
    assert configs.get_arch("phi3.5-moe-42b-a6.6b").segments[0].pattern[0].cfg.n_experts == 16
    # hymba ssm state
    assert configs.get_arch("hymba-1.5b").segments[1].pattern[0].cfg.ssm_state == 16
