"""Zero-recompile pins on the warm hot paths (tools/lint/recompile_guard).

The PR-2/PR-4 cache-key contract: `pow2_bucket` pads task counts (and the
serve batcher pads micro-batches) so every in-bucket batch size reuses one
jit cache entry.  These tests warm each hot path once, then drive it with
*different* task counts inside the same pow2 bucket and assert the XLA
compile counter does not move.  A failure here means a cache key or the
bucketing broke — the exact regression GL109 (jit-per-call) guards
statically.
"""
import jax
import pytest

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.serve import DSEServer, ServeConfig


@pytest.fixture(scope="module")
def engine(tiny_gan_cfg, small_dataset):
    model = DnnWeaverModel()
    cfg = tiny_gan_cfg(model)
    eng = GANDSE(model, cfg,
                 ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(model, n=256)
    eng.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    return eng


def test_explore_batch_in_bucket_zero_recompiles(engine, no_recompile):
    """5/6/7-task batches all pad to the pow2 bucket 8: after an 8-task
    warmup, none of them may compile anything new."""
    warm = generate_tasks(engine.model, 8, seed=11)
    engine.explore_batch(warm, seed=101)        # warm bucket 8 end to end
    with no_recompile(label="explore_batch in-bucket"):
        for n, seed in ((5, 202), (6, 303), (7, 404)):
            tasks = generate_tasks(engine.model, n, seed=seed)
            results = engine.explore_batch(tasks, seed=seed)
            assert len(results) == n


def test_fused_route_zero_recompiles_across_candidate_counts(engine,
                                                             no_recompile):
    """The fused tiled program is static in everything but the task
    bucket: threshold and cap are traced arguments and the tile-loop trip
    count is ceil(max(total)/tile) computed on device, so warm dispatches
    with different candidate counts — including caps that span one tile
    vs several — reuse ONE compiled program.  (The dense route cannot
    pass this: its C_pad bucket is a static shape picked by a host sync.)
    """
    warm = generate_tasks(engine.model, 8, seed=11)
    engine.explore_batch(warm, seed=101)        # warm bucket 8 at cap 128
    cfg = engine.explorer_cfg
    base = (cfg.prob_threshold, cfg.max_candidates)
    try:
        with no_recompile(label="fused route across candidate counts"):
            counts_seen = set()
            for thresh, cap, n, seed in ((0.30, 32, 5, 17),
                                         (0.05, 64, 6, 23),
                                         (0.02, 256, 7, 29),
                                         (0.01, 2048, 8, 31)):  # multi-tile
                cfg.prob_threshold, cfg.max_candidates = thresh, cap
                tasks = generate_tasks(engine.model, n, seed=seed)
                results = engine.explore_batch(tasks, seed=seed)
                assert len(results) == n
                counts_seen.update(r.selection.n_candidates for r in results)
        # the sweep really produced different candidate-set sizes
        assert len(counts_seen) > 4, counts_seen
    finally:
        cfg.prob_threshold, cfg.max_candidates = base


def test_warm_serve_dispatch_zero_recompiles(engine, no_recompile):
    """Warm `DSEServer` dispatch: micro-batches of 5/6/7 distinct requests
    (cache disabled, so every round really dispatches) pad to bucket 8 and
    must reuse the warmup's compiled path."""
    srv = DSEServer(ServeConfig(max_batch=8, cache_capacity=0))
    srv.register(engine)

    def drive(n, task_seed, req_seed):
        tasks = generate_tasks(engine.model, n, seed=task_seed)
        for i in range(n):
            srv.submit(engine.model.name, tasks.net_idx[i],
                       tasks.lat_obj[i], tasks.pow_obj[i],
                       seed=req_seed + i)
        responses = srv.drain()
        assert len(responses) == n

    batches0 = srv.stats["batches"]
    drive(8, 21, 1000)                          # warm bucket 8
    with no_recompile(label="warm serve dispatch"):
        drive(5, 22, 2000)
        drive(6, 23, 3000)
        drive(7, 24, 4000)
    assert srv.stats["batches"] == batches0 + 4   # all four really dispatched


def test_swap_path_zero_recompiles(tiny_gan_cfg, small_dataset, no_recompile):
    """Hot-swap is parameter-only: after warming a bucket, swapping in new
    generator params (same shapes) and re-dispatching inside the same
    bucket must not compile anything — the online loop swaps once per
    generation, so a retrace here would stall serving every few seconds."""
    model = DnnWeaverModel()
    cfg = tiny_gan_cfg(model)
    eng = GANDSE(model, cfg,
                 ExplorerConfig(prob_threshold=0.1, max_candidates=128))
    ds = small_dataset(model, n=256)
    params_a = G.init_generator(jax.random.PRNGKey(3), cfg, model.space)
    params_b = G.init_generator(jax.random.PRNGKey(9), cfg, model.space)
    eng.attach(ds, params_a)

    srv = DSEServer(ServeConfig(max_batch=8, cache_capacity=0))
    srv.register(eng)

    def drive(n, task_seed, req_seed):
        tasks = generate_tasks(model, n, seed=task_seed)
        for i in range(n):
            srv.submit(model.name, tasks.net_idx[i],
                       tasks.lat_obj[i], tasks.pow_obj[i],
                       seed=req_seed + i)
        assert len(srv.drain()) == n

    drive(8, 31, 1000)              # warm bucket 8 with generation-0 params
    with no_recompile(label="swap + redispatch"):
        srv.swap(model.name, ds, params_b)
        drive(6, 32, 2000)
        srv.swap(model.name, ds, params_a)   # swap back — still warm
        drive(7, 33, 3000)
    assert srv.stats["swaps"] == 2
