"""Distribution tests: sharding rules, multi-device compile, train driver
fault tolerance, serving engine.

These run on however many devices the host exposes (1 on CI); the
multi-device paths are additionally exercised by launch/dryrun.py with 512
placeholder devices (see EXPERIMENTS.md §Dry-run).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, Shape, applicable
from repro.launch.mesh import make_host_mesh
from repro.models import base as MB
from repro.train import shardings as SH
from repro.train import step as TS


@pytest.mark.slow
def test_param_specs_divisibility():
    """Every spec'd axis divides the param dim on the production mesh for
    every FULL architecture (structural check, no allocation)."""
    import os
    mesh_axes = {"data": 16, "model": 16}

    class FakeMesh:
        shape = mesh_axes
        devices = np.empty((16, 16), object)

    mesh = FakeMesh()
    for arch in configs.list_archs():
        m = configs.get_arch(arch)
        ps = TS.param_structs(m)
        specs = SH.param_specs(ps, mesh)
        leaves_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        leaves_p = jax.tree_util.tree_leaves(ps)
        for spec, leaf in zip(leaves_s, leaves_p):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                size = (np.prod([mesh_axes[a] for a in ax])
                        if isinstance(ax, tuple) else mesh_axes[ax])
                assert dim % size == 0, (arch, leaf.shape, spec)


@pytest.mark.slow
def test_every_applicable_cell_builds():
    """build_case constructs function+structs+shardings for all 40 cells
    without allocating memory."""
    mesh = make_host_mesh()
    n = 0
    for arch in configs.list_archs():
        m = configs.get_arch(arch)
        for shape in SHAPES.values():
            if not applicable(m, shape):
                continue
            case = TS.build_case(m, shape, mesh)
            assert case.args and case.in_shardings
            n += 1
    assert n == 34      # 40 cells - 6 inapplicable long_500k


def test_train_step_compiles_and_runs_on_host_mesh():
    mesh = make_host_mesh()
    m = configs.get_reduced("qwen3-14b")
    shape = Shape("t", 32, 4, "train")
    step, optim = TS.make_train_step(m, remat=True, mesh=mesh)
    params = MB.init_params(jax.random.PRNGKey(0), m)
    opt = optim.init(params)
    batch = {
        "tokens": jnp.zeros((4, 32), jnp.int32),
        "labels": jnp.zeros((4, 32), jnp.int32),
    }
    with mesh:
        params, opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_state_spec_long_context_shards_sequence():
    mesh_axes = {"pod": 2, "data": 16, "model": 16}

    class FakeMesh:
        shape = mesh_axes
        devices = np.empty((2, 16, 16), object)

    spec = SH.state_spec((32, 1, 524288, 8, 128), FakeMesh(), batch=1)
    assert "data" in spec  # the 500k axis is sharded
    flat = [s for s in spec if s is not None]
    assert flat  # something is sharded


def test_train_driver_restart_reproducibility(tmp_path):
    """Crash + resume == uninterrupted run (same data, same checkpoints)."""
    from repro.launch import train as TR

    base = ["--arch", "stablelm-1.6b", "--steps", "12", "--batch", "4",
            "--seq", "32", "--ckpt-every", "4", "--log-every", "12"]
    h1 = str(tmp_path / "h1.json")
    TR.main(base + ["--ckpt-dir", str(tmp_path / "a"), "--history-out", h1])
    h2 = str(tmp_path / "h2.json")
    TR.main(base + ["--ckpt-dir", str(tmp_path / "b"), "--history-out", h2,
                    "--simulate-failure-at", "7"])
    import json
    a = json.load(open(h1))
    b = json.load(open(h2))
    la = {r["step"]: r["loss"] for r in a}
    lb = {r["step"]: r["loss"] for r in b}
    # final losses agree to float tolerance (same data replayed, resumed
    # from the step-4 checkpoint)
    assert abs(la[12] - lb[12]) < 5e-3


def test_serving_engine_completes_all_requests():
    from repro.launch import serve as SV
    assert SV.main(["--arch", "gemma3-1b", "--requests", "6", "--slots", "3",
                    "--max-new", "8", "--prompt-len", "6",
                    "--cache-len", "64"]) == 0


def test_moe_expert_parallel_combine_matches_oracle():
    """The e_par combine branch (experts sharded over 'model') is exact:
    multi-device mesh where E divides the model axis."""
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dryrun covers it at 512)")
    from repro.kernels import ref
    from repro.launch.mesh import make_mesh
    from repro.nn import moe as M

    n = len(jax.devices())
    mesh = make_mesh((1, n), ("data", "model"))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8 * n, 16)),
                    jnp.float32)
    p = M.moe_init(jax.random.PRNGKey(0), n, 16, 32)     # E = model size
    logits = x @ p["router"]
    idx, w = M.route_topk(logits, 2)
    with mesh, SH.use_mesh(mesh):
        y = jax.jit(lambda p, x: M.moe_apply(p, x, top_k=2,
                                             capacity_factor=8.0))(p, x)
    want = ref.moe_dispatch_ffn(x, p["w_gate"], p["w_up"], p["w_down"],
                                idx, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_production_dryrun_cell_subprocess():
    """One real production-mesh (16x16, 256 placeholder devices) cell
    lowers + compiles end-to-end — the 512-device dry-run path, exercised
    in-process-isolated so this suite's single-device jax is untouched."""
    import os
    import subprocess

    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "stablelm-1.6b", "--shape", "decode_32k", "--mesh", "single",
         "--out", "/tmp/dryrun_ci.jsonl"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "ok" in out.stdout
