"""Benchmark aggregator: one sub-bench per paper table/figure plus the
framework benches (roofline, kernels, beyond-paper mesh DSE).

  PYTHONPATH=src python -m benchmarks.run [--only table5 difficulty ...]
"""
from __future__ import annotations

import argparse
import sys
import time

# bench_shard is absent on purpose: it must own the process to inject
# --xla_force_host_platform_device_count before jax initializes — run it
# standalone (benchmarks/bench_shard.py).
BENCHES = ("kernels", "fused_train", "table5", "difficulty", "distribution",
           "losses", "mesh_dse", "roofline")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=list(BENCHES))
    args = ap.parse_args(argv)

    import importlib
    rc = 0
    for name in args.only:
        mod = importlib.import_module(f"benchmarks.bench_{name}")
        print(f"\n===== bench_{name} =====", flush=True)
        t0 = time.time()
        try:
            mod.run()
            print(f"===== bench_{name} done in {time.time()-t0:.1f}s =====",
                  flush=True)
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"===== bench_{name} FAILED: {e} =====", flush=True)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
