"""Kernel micro-benchmarks: wall time of the XLA execution paths on this
host plus interpret-mode correctness deltas vs the oracles (the TPU perf
story lives in §Roofline — CPU wall times here are only a smoke signal)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, write_json
from repro.kernels import dispatch
from repro.kernels import fused_mlp as FM
from repro.kernels import ops, ref

TRAJECTORY = "BENCH_kernels.json"


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    x = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(1024, 1024)) * 0.03, jnp.float32)
    b = jnp.zeros((1024,), jnp.float32)
    t = _time(lambda a: ops.fused_dense_relu(a, w, b), x)
    err = float(jnp.max(jnp.abs(
        ops.fused_dense_relu(x, w, b, interpret=True)
        - ref.fused_dense_relu(x, w, b))))
    out["fused_dense_relu"] = {"us_per_call": t * 1e6, "max_abs_err": err}

    # whole-MLP layer-chained megakernel (3 x 512 hidden): time the actual
    # dispatch path (TPU -> megakernel, CPU -> jnp chain), like the rows
    # above time the ops.* dispatchers
    dims = [(512, 512)] * 3 + [(512, 256)]
    ws = tuple(jnp.asarray(rng.normal(size=d) * 0.05, jnp.float32)
               for d in dims)
    bs = tuple(jnp.zeros((d[1],), jnp.float32) for d in dims)
    layers = [{"w": w, "b": b} for w, b in zip(ws, bs)]
    xm = jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)
    chain = jax.jit(lambda a: dispatch.mlp_chain(layers, a))
    t = _time(chain, xm)
    err = float(jnp.max(jnp.abs(
        FM.fused_mlp(xm, ws, bs, interpret=True) - ref.fused_mlp(xm, ws, bs))))
    out["fused_mlp_chain"] = {"us_per_call": t * 1e6, "max_abs_err": err}

    q = jnp.asarray(rng.normal(size=(1, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 512, 64)), jnp.float32)
    t = _time(lambda a: ops.flash_attention(a, k, v), q)
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, interpret=True)
        - ref.flash_attention(q, k, v))))
    out["flash_attention"] = {"us_per_call": t * 1e6, "max_abs_err": err}

    for name, row in out.items():
        print(f"[kernels] {name:18s} {row['us_per_call']:10.1f} us/call "
              f"max_err={row['max_abs_err']:.2e}", flush=True)
    write_json("kernels.json", out)
    append_trajectory(TRAJECTORY, {"bench": "kernels", **out})
    return out


def main():
    run()


if __name__ == "__main__":
    main()
