"""Exploration throughput benchmark: batched vs sequential serving path.

The paper's "negligible DSE time" claim (§7.2, Table 5) is measured one
task at a time; a production deployment serves many concurrent DSE tasks.
This bench compares, at T tasks x >= 1024 candidates each on the
high-dimension im2col space:

- **sequential**: the per-task loop (``explore_tasks(batched=False)``) —
  one G dispatch, one host ``itertools.product`` enumeration, and one
  per-task Algorithm 2 scan dispatch per task;
- **batched**: ``explore_batch`` — vmapped G inference, on-device
  mixed-radix candidate enumeration, and ONE vmapped Algorithm 2 scan for
  the whole batch.

  PYTHONPATH=src python benchmarks/bench_explore_throughput.py [--quick]

Timings are interleaved min-of-trials after a warmup pass (CPU CI boxes
are noisy).  The acceptance bar: batched >= 5x sequential at the default
scale (64 tasks, cap 2048 => every task carries > 1024 candidates).  The
script exits nonzero otherwise and appends each run to the repo-root
``BENCH_explore.json`` trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_TRAJECTORY", "BENCH_explore.json")


def build(quick: bool):
    """Random-init G at serving scale: exploration throughput does not
    depend on training quality, only on the dispatch structure."""
    model = Im2colModel()
    layers, neurons = (1, 64) if quick else (2, 256)
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=layers, neurons=neurons, batch_size=64)
    # threshold below uniform(1/13) employs every choice; the trim then caps
    # the product in (cap/2, cap], so cap=2048 guarantees > 1024 candidates
    g = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.01,
                                          max_candidates=2048))
    ds = generate_dataset(model, 512, seed=0)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    tasks = generate_tasks(model, 64, seed=2)
    return g, tasks


def run(quick: bool = False) -> Dict:
    g, tasks = build(quick)
    n_tasks = int(tasks.net_idx.shape[0])

    # warmup / compile both routes, and check the candidate-count floor
    res = g.explore_batch(tasks, seed=0)
    g.explore_tasks(tasks, seed=0, batched=False)
    n_cands = [r.selection.n_candidates for r in res]
    assert min(n_cands) >= 1024, f"scale check failed: min {min(n_cands)}"

    trials = 2 if quick else 3
    best = {"batched": float("inf"), "sequential": float("inf")}
    for _ in range(trials):                    # interleaved: noise-robust
        t0 = time.perf_counter()
        g.explore_batch(tasks, seed=0)
        best["batched"] = min(best["batched"], time.perf_counter() - t0)
        t0 = time.perf_counter()
        g.explore_tasks(tasks, seed=0, batched=False)
        best["sequential"] = min(best["sequential"], time.perf_counter() - t0)

    out = {
        "n_tasks": n_tasks,
        "n_candidates_min": int(min(n_cands)),
        "n_candidates_mean": float(np.mean(n_cands)),
        "sequential_s": best["sequential"],
        "batched_s": best["batched"],
        "tasks_per_s_sequential": n_tasks / best["sequential"],
        "tasks_per_s_batched": n_tasks / best["batched"],
        "speedup": best["sequential"] / best["batched"],
        "quick": quick,
    }
    print(f"[explore_throughput] T={n_tasks} cands>={out['n_candidates_min']} "
          f"seq={out['sequential_s']*1e3:.1f}ms "
          f"batched={out['batched_s']*1e3:.1f}ms "
          f"({out['speedup']:.1f}x, {out['tasks_per_s_batched']:.0f} tasks/s)",
          flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "explore_throughput.json"), "w") as f:
        json.dump(out, f, indent=1)
    # append to the perf trajectory so speedups accumulate across PRs
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: smaller G, fewer trials (same "
                         "64x1024+ task scale)")
    ap.add_argument("--min-speedup", type=float, default=5.0,
                    help="fail below this batched-vs-sequential ratio; use "
                         "a loose bound (e.g. 2.0) on noisy shared runners")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if out["speedup"] < args.min_speedup:
        print(f"FAIL: batched exploration only {out['speedup']:.2f}x faster "
              f"(< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: batched exploration {out['speedup']:.1f}x faster than the "
          f"sequential loop (>= {args.min_speedup:g}x bar)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
