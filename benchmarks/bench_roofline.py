"""§Roofline: render the 40-cell roofline table from the dry-run output
(results/dryrun.jsonl, produced by launch/dryrun.py)."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, write_json


def load(path=None):
    if path is None:
        opt = os.path.join(RESULTS_DIR, "dryrun_optimized.jsonl")
        path = opt if os.path.exists(opt) else os.path.join(
            RESULTS_DIR, "dryrun.jsonl")
    if not os.path.exists(path):
        return []
    from repro import configs
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            try:
                r["arch"] = configs.get_arch(r["arch"]).name  # canonical id
            except (ImportError, AttributeError):
                pass    # unknown arch in an old record: keep the raw name
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return list(recs.values())


def run(mesh="single_pod_16x16") -> list:
    recs = [r for r in load() if r["mesh"] == mesh]
    rows = []
    print(f"{'arch':22s} {'shape':12s} {'status':8s} {'tC(s)':>8s} {'tM(s)':>8s} "
          f"{'tX(s)':>8s} {'bound':>10s} {'MFU<=':>6s} {'GB/dev':>7s}")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:8s} "
                  f"{r.get('reason', r.get('error', ''))[:60]}")
            rows.append(r)
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['status']:8s} "
              f"{r['t_compute_s']:8.4f} {r['t_memory_s']:8.4f} "
              f"{r['t_collective_s']:8.4f} {r['bottleneck']:>10s} "
              f"{(r.get('mfu_bound') or 0):6.3f} "
              f"{r['bytes_per_device']/1e9:7.2f}")
        rows.append(r)
    write_json("roofline_table.json", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
