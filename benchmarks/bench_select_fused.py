"""Fused streaming select benchmark: tiled vs dense batched route.

Both routes start from the SAME generator probs (G inference excluded —
this bench isolates enumerate+score+select) on the high-dimension im2col
space and must return bit-identical Selections:

- **dense**: ``enumerate_candidates_batch`` materializes the
  (T, C_pad, n_dims) candidate tensor (peak candidate memory linear in
  the cap, host sync to pick C_pad), then ``select_batch`` walks it with
  a vmapped sequential scan;
- **fused**: ``fused_select_batch`` streams tile-sized candidate windows
  through one jitted enumerate->score->select program (peak candidate
  memory O(T * tile * d) at any cap).

  PYTHONPATH=src python benchmarks/bench_select_fused.py [--quick]

Gates, at the dense route's ceiling (cap 2**20):
- fused >= --min-speedup x dense (default 2.0) with identical Selections;
- the fused program's compiled temp footprint stays far below the dense
  candidate tensor (the peak-memory assertion);
- a cap-2**26 batch — 64x past the dense limit — completes, its compiled
  temp footprint still tile-bounded (it cannot even be expressed on the
  dense route).

Also reports the measured per-task ``select`` host-vs-device crossover
next to the configured ``selector.JAX_MIN_CANDIDATES``, and the
throughput/peak-memory table at caps 2**14 / 2**20 / 2**26 that
EXPERIMENTS.md quotes.  Appends to the ``BENCH_explore.json`` trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import (ExplorerConfig, enumerate_candidates,
                                 enumerate_candidates_batch)
from repro.core.fused_select import fused_select_batch
from repro.core.selector import (JAX_MIN_CANDIDATES, select, select_batch)
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_TRAJECTORY", "BENCH_explore.json")

GATE_CAP = 1 << 20           # the dense route's ceiling: where the gate runs
BIG_CAP = 1 << 26            # fused-only: 64x past the dense limit
TILE = 1024
BIG_TILE = 4096


def build(quick: bool):
    """Random-init G on the im2col space (12 groups, ~2.4e9 raw product:
    threshold 0.01 employs every choice, so the trim fills any cap up to
    2**26 and candidate counts land in (cap/2, cap])."""
    model = Im2colModel()
    layers, neurons = (1, 64) if quick else (2, 256)
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=layers, neurons=neurons, batch_size=64)
    g = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.01,
                                          max_candidates=GATE_CAP))
    ds = generate_dataset(model, 512, seed=0)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    n_tasks = 4 if quick else 8
    tasks = generate_tasks(model, n_tasks, seed=2)
    probs = np.asarray(g._explorer.generator_probs_device(
        tasks.net_idx, tasks.lat_obj, tasks.pow_obj, seed=0))
    return model, tasks, probs


def _same(a, b):
    if a.n_candidates != b.n_candidates or a.satisfied != b.satisfied:
        return False
    if (a.cfg_idx is None) != (b.cfg_idx is None):
        return False
    if a.cfg_idx is None:
        return True
    return (np.array_equal(a.cfg_idx, b.cfg_idx)
            and a.latency == b.latency and a.power == b.power)


def _fused_temp_bytes(model, probs, cap, net, lo, po, tile) -> int:
    """Compiled temp footprint of the (already built) fused program."""
    run = model.__dict__["_fused_select"][tile]
    compiled = run.lower(jnp.asarray(probs), jnp.float32(0.01),
                         jnp.int32(cap), jnp.asarray(net, jnp.int32),
                         jnp.asarray(lo, jnp.float32),
                         jnp.asarray(po, jnp.float32)).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def _measure_crossover(model, tasks, probs, trials: int) -> Dict:
    """Per-task `select` host-loop vs device-scan wall time over a
    candidate-count grid; the measured cutover is reported next to the
    configured selector.JAX_MIN_CANDIDATES."""
    net = tasks.net_idx[0]
    lo, po = float(tasks.lat_obj[0]), float(tasks.pow_obj[0])
    grid, crossover = {}, None
    for cap in (128, 256, 512, 1024, 2048):
        cand = enumerate_candidates(model.space, probs[0], 0.01, cap)
        best = {"host": float("inf"), "device": float("inf")}
        select(model, net, cand, lo, po, use_jax=True)        # compile
        for _ in range(trials + 1):
            t0 = time.perf_counter()
            select(model, net, cand, lo, po, use_jax=False)
            best["host"] = min(best["host"], time.perf_counter() - t0)
            t0 = time.perf_counter()
            select(model, net, cand, lo, po, use_jax=True)
            best["device"] = min(best["device"], time.perf_counter() - t0)
        grid[int(cand.shape[0])] = best
        if crossover is None and best["device"] <= best["host"]:
            crossover = int(cand.shape[0])
    return {"grid": grid, "measured_crossover": crossover,
            "configured_crossover": JAX_MIN_CANDIDATES}


def run(quick: bool = False) -> Dict:
    model, tasks, probs = build(quick)
    n_tasks = int(tasks.net_idx.shape[0])
    net = np.asarray(tasks.net_idx, np.int32)
    lo = np.asarray(tasks.lat_obj, np.float64)
    po = np.asarray(tasks.pow_obj, np.float64)
    trials = 2 if quick else 3
    d = model.space.n_dims
    caps = {}

    # ---- fused vs dense at 2**14 and at the dense ceiling 2**20 ----------
    for cap in (1 << 14, GATE_CAP):
        fused = fused_select_batch(model, net, probs, 0.01, cap, lo, po,
                                   tile=TILE)                 # warm + compile
        cand, valid, counts = enumerate_candidates_batch(
            model.space, probs, 0.01, cap)
        dense = select_batch(model, net, cand, valid, counts, lo, po)
        assert all(_same(f, x) for f, x in zip(fused, dense)), \
            f"fused != dense Selections at cap {cap}"
        assert min(counts) > cap // 2, f"scale check failed at cap {cap}"

        best = {"fused": float("inf"), "dense": float("inf")}
        for _ in range(trials):                  # interleaved: noise-robust
            t0 = time.perf_counter()
            fused_select_batch(model, net, probs, 0.01, cap, lo, po,
                               tile=TILE)
            best["fused"] = min(best["fused"], time.perf_counter() - t0)
            t0 = time.perf_counter()
            c, v, nc = enumerate_candidates_batch(model.space, probs, 0.01,
                                                  cap)
            select_batch(model, net, c, v, nc, lo, po)
            best["dense"] = min(best["dense"], time.perf_counter() - t0)

        c_pad = int(cand.shape[1])
        caps[cap] = {
            "fused_s": best["fused"],
            "dense_s": best["dense"],
            "speedup": best["dense"] / best["fused"],
            "n_candidates_min": int(min(counts)),
            "dense_cand_bytes": n_tasks * c_pad * d * 4,
            "fused_cand_bytes": n_tasks * TILE * d * 4,
            "fused_temp_bytes": _fused_temp_bytes(
                model, probs, cap, net, lo, po, TILE),
        }
        print(f"[select_fused] T={n_tasks} cap=2^{cap.bit_length()-1} "
              f"dense={best['dense']*1e3:.1f}ms "
              f"fused={best['fused']*1e3:.1f}ms "
              f"({caps[cap]['speedup']:.1f}x) "
              f"cand_bytes dense={caps[cap]['dense_cand_bytes']:.3g} "
              f"fused={caps[cap]['fused_cand_bytes']:.3g}", flush=True)

    gate = caps[GATE_CAP]
    # peak-memory assertion: the fused program's entire compiled temp
    # footprint (all live buffers, not just candidates) stays well under
    # the dense route's candidate tensor alone
    assert gate["fused_temp_bytes"] * 4 < gate["dense_cand_bytes"], \
        (gate["fused_temp_bytes"], gate["dense_cand_bytes"])

    # ---- 2**26: 64x past the dense limit, fused-only ----------------------
    big_tasks = 2 if quick else 4
    sels = fused_select_batch(model, net[:big_tasks], probs[:big_tasks], 0.01,
                              BIG_CAP, lo[:big_tasks], po[:big_tasks],
                              tile=BIG_TILE)                  # warm + compile
    t0 = time.perf_counter()
    fused_select_batch(model, net[:big_tasks], probs[:big_tasks], 0.01,
                       BIG_CAP, lo[:big_tasks], po[:big_tasks], tile=BIG_TILE)
    big_s = time.perf_counter() - t0
    big_min = min(s.n_candidates for s in sels)
    assert big_min > BIG_CAP // 2 and all(s.cfg_idx is not None for s in sels)
    big_temp = _fused_temp_bytes(model, probs[:big_tasks], BIG_CAP,
                                 net[:big_tasks], lo[:big_tasks],
                                 po[:big_tasks], BIG_TILE)
    big_dense_equiv = big_tasks * BIG_CAP * d * 4   # what dense would need
    assert big_temp * 64 < big_dense_equiv, (big_temp, big_dense_equiv)
    print(f"[select_fused] cap=2^26 T={big_tasks} cands>={big_min} "
          f"fused={big_s:.2f}s temp={big_temp:.3g}B "
          f"(dense would need {big_dense_equiv:.3g}B)", flush=True)

    crossover = _measure_crossover(model, tasks, probs, trials)
    print(f"[select_fused] select() crossover: measured="
          f"{crossover['measured_crossover']} configured="
          f"{crossover['configured_crossover']}", flush=True)

    out = {
        "bench": "select_fused",
        "n_tasks": n_tasks,
        "tile": TILE,
        "big_tile": BIG_TILE,
        "caps": {str(k): v for k, v in caps.items()},
        "speedup": gate["speedup"],
        "big_cap": BIG_CAP,
        "big_tasks": big_tasks,
        "big_s": big_s,
        "big_candidates_min": int(big_min),
        "big_temp_bytes": big_temp,
        "crossover": crossover,
        "quick": quick,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "select_fused.json"), "w") as f:
        json.dump(out, f, indent=1)
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: 4 tasks, 2 trials, 2-task 2^26 run")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="fail below this fused-vs-dense ratio at cap 2^20; "
                         "use a looser bound on noisy shared runners")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if out["speedup"] < args.min_speedup:
        print(f"FAIL: fused select only {out['speedup']:.2f}x the dense "
              f"route at cap 2^20 (< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: fused select {out['speedup']:.1f}x dense at cap 2^20 "
          f"(>= {args.min_speedup:g}x bar), 2^26 completes in "
          f"{out['big_s']:.2f}s within the tile-memory envelope")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
