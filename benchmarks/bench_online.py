"""Train-while-serve soak for the online improvement loop
(`repro.serve.online.OnlineLoop` over `ServeFrontend`).

Scenario: one random-init engine serves waves of deliberately hard
requests (objective slack pinned at 1.0: every objective sits exactly on
a sampled design point, which a small-candidate random generator mostly
misses).  The online loop harvests the unsatisfied responses, mines hard
examples, fine-tunes incrementally, checkpoints, and hot-swaps each
generation into the live front end while the next wave is being served.

The run FAILS (nonzero exit) unless:

- **improvement**: >= 3 swap generations complete, and the satisfied
  -rate on a *held-out* hard-task stream — exactly-Pareto tasks from a
  seed no wave ever serves, evaluated after the fact by restoring each
  generation's checkpoint into a scratch engine, in the headline
  thresholded-candidate regime of ``experiments/run_comparison.py`` —
  strictly improves from generation 0 to the last generation (training
  on witnesses mined from served-traffic negatives must generalize, the
  paper's §6.2 insight made operational);
- **latency**: served p99 with the trainer running stays within
  ``--max-p99-ratio`` (default 1.25x) of a no-trainer baseline pushing
  identical traffic — background training must not starve serving;
- **no wedged requests**: every submitted future terminates, in both
  runs, including one deliberately corrupted checkpoint generation at
  the end (swap detects the damage at read-back and falls back to the
  previous good generation while serving continues);
- **zero recompiles on the swap path**: with buckets warm, swapping a
  trained generation in and re-dispatching in-bucket triggers no XLA
  compilation (hot swap means *hot* — params-only attach).

Results append to the repo-root ``BENCH_online.json`` trajectory (latest
copy in ``results/online_serving.json``).

  PYTHONPATH=src python benchmarks/bench_online.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Tuple

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.serve import (DSEServer, FrontendConfig, OnlineConfig, OnlineLoop,
                         ServeConfig, ServeFrontend, corrupt_checkpoint)
from tools.lint.recompile_guard import track_compiles

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_ONLINE_TRAJECTORY",
                            "BENCH_online.json")

MAX_BATCH = 8
MAX_CANDIDATES = 64     # serving stack: small trim cap keeps dispatch fast
                        # (the p99 gate measures the serving loop, not
                        # exploration width)
HARD_SLACK = (1.0, 1.0)       # served waves: exactly-Pareto objectives
EVAL_SEED = 1                 # held-out exploration seed for the per
                              # -generation eval: never used by a served
                              # request, so the eval shares no noise draw
                              # (and no cache entries) with serving
# The improvement eval runs in the repo's headline regime
# (experiments/run_comparison.py): thresholded candidate output with a
# generous trim cap.  Under a tight cap (e.g. the serving stack's 64) a
# random-init G fills the cap with diffuse candidates — brute-force
# lottery tickets that mask conditioning quality entirely, the exact
# failure mode Scale.quick()'s docstring warns about.  Thresholding lets
# each generation spend only the candidates it believes in, so the
# satisfied-rate measures what training changes: conditioning.
EVAL_THRESHOLD = 0.2
EVAL_MAX_CANDIDATES = 2048
HELD_SEED = 777         # task seed for the held-out eval stream: never a
                        # wave seed (10..w), warmup seed (91), or recovery
                        # seed (5000/6000/7000), so no served request ever
                        # sees these tasks and no mined witness targets them


def build_engine(quick: bool, seed: int = 0
                 ) -> Tuple[DnnWeaverModel, G.GANConfig, GANDSE, object]:
    model = DnnWeaverModel()
    # deliberately small G in BOTH modes: the bench measures the serving
    # loop, not model capacity, and a small generator leaves headroom for
    # the improvement signal (a lucky large random init can start near its
    # trained quality, drowning the gate in init noise)
    gan_cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=1, neurons=32, batch_size=64)
    eng = GANDSE(model, gan_cfg, ExplorerConfig(
        prob_threshold=0.1, max_candidates=MAX_CANDIDATES))
    ds = generate_dataset(model, 256 if quick else 512, seed=seed)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 3)
    eng.attach(ds, G.init_generator(key, gan_cfg, model.space))
    return model, gan_cfg, eng, ds


def warmup_dispatch(eng, model, seed: int = 91) -> None:
    """Compile every pow2 micro-batch bucket the waves will hit."""
    k = 1
    while k <= MAX_BATCH:
        tasks = generate_tasks(model, k, seed=seed)
        eng.explore_tasks(tasks, seed=np.arange(k))
        k *= 2


def serve_wave(fe: ServeFrontend, model, wave_size: int, wave_seed: int,
               req_base: int) -> Dict:
    """One wave of hard requests with fresh request seeds (no cache hits);
    returns the wave's satisfied count, p99, and wedged-future count."""
    tasks = generate_tasks(model, wave_size, seed=wave_seed,
                           slack=HARD_SLACK)
    lat: List[float] = []
    futs = []
    for i in range(wave_size):
        t0 = time.perf_counter()
        fut = fe.submit(model.name, tasks.net_idx[i], tasks.lat_obj[i],
                        tasks.pow_obj[i], seed=req_base + i)
        fut.add_done_callback(
            lambda _f, t=t0: lat.append(time.perf_counter() - t))
        futs.append(fut)
    fe.wait_all(timeout=300.0)
    return {
        "wedged": sum(1 for f in futs if not f.done()),
        "sat": sum(1 for f in futs if f.done() and f.result().ok
                   and f.result().result.satisfied),
        "p99": (float(np.percentile(np.asarray(lat) * 1e3, 99))
                if lat else float("nan")),
    }


def trainer_catchup(loop: OnlineLoop, min_hard: int,
                    timeout_s: float = 120.0) -> None:
    """Wait until the trainer is fully caught up (buffer below the trigger
    AND no generation mid-flight) so the next timed wave's latencies are
    not polluted by a CPU-stealing training burst."""
    deadline = time.monotonic() + timeout_s
    while ((len(loop.buffer) >= min_hard or loop.training)
           and time.monotonic() < deadline):
        time.sleep(0.05)


def eval_generations(model, gan_cfg, ds, ckpt, steps, hard, like
                     ) -> List[Dict]:
    """Satisfied-rate of each checkpointed generation on the held-out
    hard stream, explored under EVAL_SEED via a scratch engine — the
    serving stack is out of the loop, so this measures generator quality
    alone.  The stream is *generated* (HELD_SEED), not harvested from
    the soak's own unsatisfied responses: a harvested stream is
    adversarially selected against whichever generation was serving when
    each row was recorded, so its late rows are precisely the tasks the
    *trained* generations fail on — a gate on it runs backwards.  A
    fixed pre-generated stream instead asks whether training on mined
    served-traffic witnesses generalizes to unseen exactly-Pareto tasks.
    Runs in the headline thresholded regime (see EVAL_THRESHOLD above)
    and also reports each generation's candidate spend."""
    scratch = GANDSE(model, gan_cfg, ExplorerConfig(
        prob_threshold=EVAL_THRESHOLD,
        max_candidates=EVAL_MAX_CANDIDATES))
    out = []
    for step in steps:
        params = ckpt.restore(step, like)
        scratch.attach(ds, params)
        results = scratch.explore_tasks(hard, seed=EVAL_SEED)
        sat = sum(1 for r in results if r.satisfied)
        cand = sum(r.selection.n_candidates for r in results)
        out.append({"step": step, "satisfied": sat, "n": len(results),
                    "candidates": cand})
    return out


def run(quick: bool, max_p99_ratio: float) -> Tuple[Dict, List[str]]:
    waves = 6 if quick else 8
    wave_size = 16 if quick else 24
    min_hard = 8    # a hard wave yields ~6-10 unsatisfied: a trained
                    # generation roughly every other wave
    n_held = 32 if quick else 48
    failures: List[str] = []

    # two identical stacks (same init seed -> bit-identical params): one
    # carries the online loop, the other is the no-trainer control.  Waves
    # are INTERLEAVED in time — online wave w, trainer catch-up, then
    # baseline wave w — so machine-level throughput drift (CPU frequency,
    # page cache, co-tenants) hits both latency samples equally instead of
    # biasing whichever run was measured second.
    model_b, _, eng_b, _ = build_engine(quick)
    warmup_dispatch(eng_b, model_b)
    srv_b = DSEServer(ServeConfig(max_batch=MAX_BATCH))
    srv_b.register(eng_b)

    model, gan_cfg, eng, ds = build_engine(quick)
    warmup_dispatch(eng, model)
    srv = DSEServer(ServeConfig(max_batch=MAX_BATCH))
    srv.register(eng)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_online_")
    ocfg = OnlineConfig(min_hard=min_hard, train_iters=4, mine_samples=128,
                        replay_capacity=32,
                        keep_last_n=0,     # retain every generation: the
                                           # improvement gate replays them
                        seed=0)
    base_run = {"sat_per_wave": [], "p99_per_wave": [], "wedged": 0}
    online_run = {"sat_per_wave": [], "p99_per_wave": [], "wedged": 0}
    rng = np.random.default_rng(0)

    t0 = time.time()
    with ServeFrontend(srv_b, FrontendConfig()) as fe_b, \
            ServeFrontend(srv, FrontendConfig()) as fe:
        with OnlineLoop(fe, model.name, ckpt_dir, cfg=ocfg) as loop:
            loop.warmup()                  # compile the epoch fn up front
            with track_compiles() as soak_rec:
                for w in range(waves):
                    req = int(rng.integers(1 << 20)) * 1000  # fresh seeds:
                    o = serve_wave(fe, model, wave_size,     # no cache hits
                                   wave_seed=10 + w, req_base=req)
                    online_run["sat_per_wave"].append(o["sat"])
                    online_run["p99_per_wave"].append(round(o["p99"], 2))
                    online_run["wedged"] += o["wedged"]
                    trainer_catchup(loop, min_hard)
                    b = serve_wave(fe_b, model_b, wave_size,
                                   wave_seed=10 + w, req_base=req)
                    base_run["sat_per_wave"].append(b["sat"])
                    base_run["p99_per_wave"].append(round(b["p99"], 2))
                    base_run["wedged"] += b["wedged"]
        final = loop.metrics()

        # --- corrupted-generation recovery, on the still-live front end --
        loop.cfg.post_checkpoint = lambda sdir: corrupt_checkpoint(sdir)
        pre_step = final["serving_step"]
        rec_run = serve_wave(fe, model, wave_size, wave_seed=5000,
                             req_base=int(rng.integers(1 << 20)) * 1000)
        loop.run_generation()              # synchronous: checkpoint damaged
        recovery = {"serving_step": loop.serving_step,
                    "swap_fallbacks": loop.counters["swap_fallbacks"],
                    "wedged": rec_run["wedged"]}
        post_run = serve_wave(fe, model, wave_size, wave_seed=6000,
                              req_base=int(rng.integers(1 << 20)) * 1000)
        recovery["wedged"] += post_run["wedged"]

        # --- swap-path recompile pin, warm buckets + trained params ------
        like = loop.ckpt.restore(final["serving_step"],
                                 loop.engine.g_params)
        with track_compiles() as swap_rec:
            fe.swap(model.name, ds, like)
            pin_run = serve_wave(fe, model, wave_size, wave_seed=7000,
                                 req_base=int(rng.integers(1 << 20)) * 1000)
        recovery["wedged"] += pin_run["wedged"]
    wall = time.time() - t0

    # gate statistic: the MEDIAN of per-wave online/baseline p99 ratios.
    # A per-wave p99 over 16 samples is essentially that wave's max, so a
    # single OS/GC hiccup would set a whole-run p99; pairing each online
    # wave with the baseline wave measured right next to it and taking the
    # median rejects one-off outliers while systematic trainer-induced
    # starvation (every wave slowed) still fails the gate.
    base_run["p99_ms"] = float(np.median(base_run["p99_per_wave"]))
    online_run["p99_ms"] = float(np.median(online_run["p99_per_wave"]))
    p99_ratio = float(np.median([o / max(b, 1e-9) for o, b in zip(
        online_run["p99_per_wave"], base_run["p99_per_wave"])]))

    print(f"[online] baseline: sat/wave={base_run['sat_per_wave']} "
          f"p99/wave={base_run['p99_per_wave']}ms "
          f"wedged={base_run['wedged']} "
          f"(backend={jax.default_backend()})", flush=True)
    print(f"[online] soak: sat/wave={online_run['sat_per_wave']} "
          f"p99/wave={online_run['p99_per_wave']}ms "
          f"ratio={p99_ratio:.2f}x wedged={online_run['wedged']} "
          f"generations={final['generations']} swaps={final['swaps']} "
          f"fallbacks={final['swap_fallbacks']} "
          f"errors={final['generation_errors']} "
          f"mined={final['mined_rows']} "
          f"soak_compiles={soak_rec.count} wall={wall:.1f}s", flush=True)

    # improvement trajectory across checkpointed generations, on the
    # held-out hard stream (the post-soak recovery step's checkpoint is
    # deliberately corrupt: skip everything past the last clean generation)
    held = generate_tasks(model, n_held, seed=HELD_SEED, slack=HARD_SLACK)
    trained_steps = [s for s in loop.ckpt.steps()
                     if s <= final["generations"]]
    traj = eval_generations(model, gan_cfg, ds, loop.ckpt, trained_steps,
                            held, loop.engine.g_params)
    print(f"[online] held-out hard stream (step, satisfied, candidates): "
          f"{[(t['step'], t['satisfied'], t['candidates']) for t in traj]}"
          f" of {n_held}", flush=True)

    # --- gates -----------------------------------------------------------
    if final["generations"] < 3:
        failures.append(f"only {final['generations']} swap generations "
                        f"completed (need >= 3)")
    if traj and not traj[-1]["satisfied"] > traj[0]["satisfied"]:
        failures.append(
            f"held-out satisfied-rate did not improve: generation "
            f"{traj[0]['step']} -> {traj[0]['satisfied']}/{n_held}, "
            f"generation {traj[-1]['step']} -> "
            f"{traj[-1]['satisfied']}/{n_held}")
    if p99_ratio > max_p99_ratio:
        failures.append(f"online p99 {online_run['p99_ms']:.1f}ms is "
                        f"{p99_ratio:.2f}x the no-trainer baseline "
                        f"{base_run['p99_ms']:.1f}ms "
                        f"(bound {max_p99_ratio:.2f}x)")
    total_wedged = (base_run["wedged"] + online_run["wedged"]
                    + recovery["wedged"])
    if total_wedged:
        failures.append(f"{total_wedged} request(s) never terminated")
    if final["generation_errors"]:
        failures.append(f"{final['generation_errors']} trainer generation(s) "
                        f"raised: {final['last_error']}")
    if recovery["swap_fallbacks"] != final["swap_fallbacks"] + 1:
        failures.append("corrupted checkpoint did not trigger exactly one "
                        "swap fallback")
    if recovery["serving_step"] != pre_step:
        failures.append(f"corrupted generation was served (step "
                        f"{recovery['serving_step']}, expected fallback to "
                        f"{pre_step})")
    if swap_rec.count:
        failures.append(f"{swap_rec.count} XLA compilation(s) on the warm "
                        f"swap path (hot swap must be params-only)")

    out = {
        "quick": quick,
        "backend": jax.default_backend(),
        "waves": waves,
        "wave_size": wave_size,
        "max_candidates": MAX_CANDIDATES,
        "baseline": base_run,
        "online": online_run,
        "generations": final["generations"],
        "swaps": final["swaps"],
        "swap_fallbacks": final["swap_fallbacks"],
        "mined_rows": final["mined_rows"],
        "buffer": final["buffer"],
        "held_out_by_generation": traj,
        "held_out_size": n_held,
        "p99_ratio": p99_ratio,
        "soak_compiles": soak_rec.count,
        "swap_path_compiles": swap_rec.count,
        "recovery": recovery,
        "wall_s": wall,
        "ok": not failures,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "online_serving.json"), "w") as f:
        json.dump(out, f, indent=1)
    hist = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            hist = json.load(f)
    hist.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(hist, f, indent=1)
    return out, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: 4 waves of 16, smaller G")
    ap.add_argument("--max-p99-ratio", type=float, default=1.25,
                    help="fail if online p99 exceeds this multiple of the "
                         "no-trainer baseline p99")
    args = ap.parse_args(argv)
    _, failures = run(quick=args.quick, max_p99_ratio=args.max_p99_ratio)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("ok: satisfied-rate improved across generations, p99 within "
          "budget, no wedged requests, corrupted swap fell back, swap "
          "path stayed compile-free")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
