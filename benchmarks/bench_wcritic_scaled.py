"""Scaled-up validation of the paper's core claim (ii): on the
high-dimension im2col model, training WITH the discriminator
(w_critic > 0) finds more satisfying designs than w_critic = 0, and the
gap widens on hard (near-Pareto) objectives.

Bigger G/D (4 x 512 vs the quick benches' 3 x 256), longer training, and
hard tasks (slack 1.0-1.6).  Not part of the default `benchmarks.run`
set — invoked explicitly (results recorded in EXPERIMENTS.md §Repro):

  PYTHONPATH=src python -m benchmarks.bench_wcritic_scaled
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_json
from repro.baselines.sa import SimulatedAnnealing
from repro.core.dse_api import GANDSE, summarize
from repro.core.gan import GANConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel

LAYERS = 4
NEURONS = 512
ITERS = 24
N_DATA = 16000
N_TASKS = 300
SLACK = (1.0, 1.6)


def run() -> dict:
    model = Im2colModel()
    ds = generate_dataset(model, N_DATA, seed=0)
    tasks = generate_tasks(model, N_TASKS, seed=1, slack=SLACK)
    out = {"scale": dict(layers=LAYERS, neurons=NEURONS, iters=ITERS,
                         n_data=N_DATA, n_tasks=N_TASKS, slack=SLACK)}
    rows = []

    sa = SimulatedAnnealing(model)
    s = summarize(sa.explore_tasks(tasks))
    s.update(method="SA", w_critic=None, train_time_s=0.0)
    rows.append(s)
    print(f"[wcritic] SA       sat={s['n_satisfied']}/{s['n_tasks']} "
          f"impr={s['improvement_ratio']:.4f}", flush=True)

    for w in (0.0, 0.5, 1.0):
        cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=w).scaled(
            layers=LAYERS, neurons=NEURONS, lr=1e-4, batch_size=512)
        g = GANDSE(model, cfg)
        t0 = time.time()
        g.train(n_data=N_DATA, iters=ITERS, seed=0, ds=ds, log_every=8)
        t_train = time.time() - t0
        s = summarize(g.explore_tasks(tasks))
        s.update(method="GAN", w_critic=w, train_time_s=round(t_train, 1))
        # D accuracy at end of training (is the critic informative?)
        s["final_d_acc"] = float(np.mean(
            [h["d_acc"] for h in g.state.history[-20:]]))
        s["final_critic_loss"] = float(np.mean(
            [h["loss_critic"] for h in g.state.history[-20:]]))
        rows.append(s)
        print(f"[wcritic] GAN w={w} sat={s['n_satisfied']}/{s['n_tasks']} "
              f"impr={s['improvement_ratio']:.4f} d_acc={s['final_d_acc']:.3f} "
              f"critic={s['final_critic_loss']:.3f} train={t_train:.0f}s",
              flush=True)

    out["rows"] = rows
    write_json("wcritic_scaled.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
