"""Fused-MLP fast-path benchmark: Algorithm 1 train step + vmapped-G
inference, fused (Pallas) vs unfused (jnp).

GANDSE's compute budget is the deep ReLU G/D MLPs; the fused path runs
them through the Pallas dense+bias+ReLU kernels (custom_vjp backward for
training, the layer-chained megakernel for inference) behind the
``kernels/dispatch.py`` backend rule.

  PYTHONPATH=src python benchmarks/bench_fused_train.py [--quick]

On TPU the bench times both routes and gates the fused train step at
>= 1.5x the unfused one (``--min-speedup``).  Off TPU the compiled Pallas
path does not exist (the dispatch rule sends both configs to jnp), so the
speedup gate auto-skips and the bench instead *gates parity*: forward and
``jax.grad`` through ``fused_dense`` and the megakernel in interpret mode
must match the jnp reference to <= 1e-4 — CPU CI validates the exact
kernel code TPU compiles.  Every run appends to the repo-root
``BENCH_kernels.json`` trajectory (latest copy in
``results/fused_train.json``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gan as G
from repro.core import train as T
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel
from repro.kernels import fused_mlp as FM
from repro.kernels import ref

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_TRAJECTORY", "BENCH_kernels.json")
PARITY_TOL = 1e-4


def _time(fn, iters: int) -> float:
    jax.block_until_ready(fn())          # warmup / compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _build(quick: bool):
    model = Im2colModel()
    layers, neurons, bs = (2, 128, 128) if quick else (3, 512, 512)
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=layers, neurons=neurons, batch_size=bs)
    ds = generate_dataset(model, max(bs * 2, 512), seed=0)
    return model, cfg, ds


def _bench_train_step(model, cfg, ds, iters: int) -> float:
    """Min wall time of one jitted Algorithm 1 step at cfg.batch_size."""
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    gp = G.init_generator(r1, cfg, model.space)
    dp = G.init_discriminator(r2, cfg, model.space)
    g_optim, d_optim, step = T.make_train_step(model, cfg)
    go, do = g_optim.init(gp), d_optim.init(dp)
    batch = {k: jnp.asarray(v) for k, v in
             T.encode_batch(model, ds, np.arange(cfg.batch_size)).items()}
    return _time(lambda: step(gp, dp, go, do, batch, r3), iters)


def _bench_inference(model, cfg, ds, n_tasks: int, iters: int) -> float:
    """Min wall time of the vmapped noise-averaged G forward (the
    explorer/serve dispatch hot spot) over a task batch."""
    engine = GANDSE(model, cfg, ExplorerConfig(noise_samples=4))
    engine.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg,
                                       model.space))
    tasks = generate_tasks(model, n_tasks, seed=1)
    ex = engine._explorer
    return _time(lambda: ex.generator_probs_device(
        tasks.net_idx, tasks.lat_obj, tasks.pow_obj, seed=0), iters)


def _parity() -> Dict[str, float]:
    """Interpret-mode fused-vs-jnp parity, forward AND grad (the off-TPU
    gate): max abs error across fused_dense (both relu modes) and the
    layer-chained megakernel."""
    rng = np.random.default_rng(0)
    m, k, n = 96, 160, 80
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    ct = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    out = {}
    for relu in (True, False):
        ref_fn = ref.fused_dense_relu if relu else ref.fused_dense
        fwd_err = jnp.max(jnp.abs(
            FM.fused_dense(x, w, b, relu=relu, interpret=True)
            - ref_fn(x, w, b)))
        g_f = jax.grad(lambda *a: jnp.sum(FM.fused_dense(
            *a, relu=relu, interpret=True) * ct), argnums=(0, 1, 2))(x, w, b)
        g_r = jax.grad(lambda *a: jnp.sum(ref_fn(*a) * ct),
                       argnums=(0, 1, 2))(x, w, b)
        grad_err = max(float(jnp.max(jnp.abs(a - bb)))
                       for a, bb in zip(g_f, g_r))
        tag = "relu" if relu else "linear"
        out[f"fused_dense_{tag}_fwd_err"] = float(fwd_err)
        out[f"fused_dense_{tag}_grad_err"] = grad_err

    dims = [(37, 64), (64, 64), (64, 29)]
    xm = jnp.asarray(rng.normal(size=(33, 37)), jnp.float32)
    ws = tuple(jnp.asarray(rng.normal(size=d) * 0.1, jnp.float32)
               for d in dims)
    bs = tuple(jnp.asarray(rng.normal(size=(d[1],)), jnp.float32)
               for d in dims)
    ctm = jnp.asarray(rng.normal(size=(33, 29)), jnp.float32)
    out["megakernel_fwd_err"] = float(jnp.max(jnp.abs(
        FM.fused_mlp(xm, ws, bs, interpret=True) - ref.fused_mlp(xm, ws, bs))))
    g_f = jax.grad(lambda *a: jnp.sum(FM.fused_mlp(
        *a, interpret=True) * ctm), argnums=(0, 1, 2))(xm, ws, bs)
    g_r = jax.grad(lambda *a: jnp.sum(ref.fused_mlp(*a) * ctm),
                   argnums=(0, 1, 2))(xm, ws, bs)
    errs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_f, g_r))
    out["megakernel_grad_err"] = max(errs)
    return out


def run(quick: bool = False) -> Dict:
    model, cfg, ds = _build(quick)
    fused_cfg = dataclasses.replace(cfg, use_fused=True)
    unfused_cfg = dataclasses.replace(cfg, use_fused=False)
    on_tpu = jax.default_backend() == "tpu"
    iters = 3 if quick else 5
    n_tasks = 32 if quick else 64

    out = {
        "backend": jax.default_backend(),
        "on_tpu": on_tpu,
        "layers": cfg.g_hidden_layers,
        "neurons": cfg.g_neurons,
        "batch_size": cfg.batch_size,
        "quick": quick,
    }
    out["train_step_unfused_s"] = _bench_train_step(model, unfused_cfg, ds,
                                                    iters)
    out["train_step_fused_s"] = _bench_train_step(model, fused_cfg, ds, iters)
    out["infer_unfused_s"] = _bench_inference(model, unfused_cfg, ds,
                                              n_tasks, iters)
    out["infer_fused_s"] = _bench_inference(model, fused_cfg, ds, n_tasks,
                                            iters)
    out["train_speedup"] = out["train_step_unfused_s"] / out["train_step_fused_s"]
    out["infer_speedup"] = out["infer_unfused_s"] / out["infer_fused_s"]
    out["parity"] = _parity()
    out["parity_max_err"] = max(out["parity"].values())

    print(f"[fused_train] backend={out['backend']} "
          f"step unfused={out['train_step_unfused_s']*1e3:.1f}ms "
          f"fused={out['train_step_fused_s']*1e3:.1f}ms "
          f"({out['train_speedup']:.2f}x)  "
          f"infer {out['infer_unfused_s']*1e3:.1f} -> "
          f"{out['infer_fused_s']*1e3:.1f}ms ({out['infer_speedup']:.2f}x)  "
          f"parity_max_err={out['parity_max_err']:.2e}", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "fused_train.json"), "w") as f:
        json.dump(out, f, indent=1)
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append({"bench": "fused_train", **out})
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: smaller nets, fewer trials")
    ap.add_argument("--min-speedup", type=float, default=1.5,
                    help="fused-vs-unfused train-step bar (TPU only; the "
                         "dispatch rule makes both routes identical jnp "
                         "off-TPU, so the gate auto-skips there)")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if out["parity_max_err"] > PARITY_TOL:
        print(f"FAIL: fused-vs-jnp parity {out['parity_max_err']:.2e} "
              f"(> {PARITY_TOL:g} tolerance)")
        return 1
    if not out["on_tpu"]:
        print(f"ok: parity <= {PARITY_TOL:g}; speedup gate skipped "
              f"(backend={out['backend']}, fused path is TPU-only)")
        return 0
    if out["train_speedup"] < args.min_speedup:
        print(f"FAIL: fused train step only {out['train_speedup']:.2f}x "
              f"(< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: fused train step {out['train_speedup']:.2f}x, inference "
          f"{out['infer_speedup']:.2f}x (>= {args.min_speedup:g}x bar)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
