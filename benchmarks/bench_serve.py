"""Serving throughput benchmark: micro-batched vs one-request-at-a-time.

The paper's "negligible DSE time" (Table 5) is a per-query number; the
ROADMAP north star is sustained throughput under many concurrent queries.
This bench pushes 64 in-flight requests through two `DSEServer` instances
over the same engine (im2col space, >= 1024 candidates per task):

- **sequential**: ``max_batch=1`` — the one-request-at-a-time serving
  loop (one dispatch chain per request, the Table-5 measurement mode);
- **batched**: ``max_batch=64`` — the requests coalesce into one pow2
  -bucketed micro-batch per drain.

  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]

Requests carry unique seeds and the result cache is disabled, so both
servers do all 64 explorations for real.  Timings are interleaved min-of
-trials after a warmup pass.  Acceptance bar: batched >= 3x sequential.
The script exits nonzero otherwise and appends each run to the repo-root
``BENCH_serve.json`` trajectory (latest copy in
``results/serve_throughput.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import numpy as np

from repro.core import gan as G
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel
from repro.serve import DSEServer, ServeConfig

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_TRAJECTORY", "BENCH_serve.json")

N_REQUESTS = 64


def build(quick: bool):
    """Random-init G at serving scale (same rationale as
    bench_explore_throughput: throughput depends on dispatch structure,
    not training quality)."""
    model = Im2colModel()
    layers, neurons = (1, 64) if quick else (2, 256)
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=layers, neurons=neurons, batch_size=64)
    # threshold below uniform employs every choice; trim caps the product in
    # (cap/2, cap], so cap=2048 guarantees > 1024 candidates per task
    engine = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.01,
                                               max_candidates=2048))
    ds = generate_dataset(model, 512, seed=0)
    engine.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    tasks = generate_tasks(model, N_REQUESTS, seed=2)
    return engine, tasks


def make_server(engine, max_batch: int) -> DSEServer:
    # cache off: both modes must do all the work every trial
    srv = DSEServer(ServeConfig(max_batch=max_batch, cache_capacity=0))
    srv.register(engine)
    return srv


def push(srv: DSEServer, engine, tasks, seed0: int) -> float:
    """Submit all requests (unique seeds), drain, return the wall time."""
    n = len(tasks)
    t0 = time.perf_counter()
    for i in range(n):
        srv.submit(engine.model.name, tasks.net_idx[i], tasks.lat_obj[i],
                   tasks.pow_obj[i], seed=seed0 + i)
    resp = srv.drain()
    dt = time.perf_counter() - t0
    assert len(resp) == n, (len(resp), n)
    return dt


def run(quick: bool = False) -> Dict:
    engine, tasks = build(quick)
    seq = make_server(engine, max_batch=1)
    bat = make_server(engine, max_batch=N_REQUESTS)

    # warmup / compile both serving routes; check the candidate-count floor
    push(bat, engine, tasks, seed0=0)
    push(seq, engine, tasks, seed0=0)
    n_cands = [bat.response(r).result.selection.n_candidates
               for r in range(N_REQUESTS)]
    assert min(n_cands) >= 1024, f"scale check failed: min {min(n_cands)}"

    trials = 2 if quick else 3
    best = {"batched": float("inf"), "sequential": float("inf")}
    for _ in range(trials):                    # interleaved: noise-robust
        best["batched"] = min(best["batched"], push(bat, engine, tasks, 0))
        best["sequential"] = min(best["sequential"], push(seq, engine, tasks, 0))

    out = {
        "n_requests": N_REQUESTS,
        "n_candidates_min": int(min(n_cands)),
        "n_candidates_mean": float(np.mean(n_cands)),
        "sequential_s": best["sequential"],
        "batched_s": best["batched"],
        "req_per_s_sequential": N_REQUESTS / best["sequential"],
        "req_per_s_batched": N_REQUESTS / best["batched"],
        "batches_batched": bat.stats["batches"],
        "speedup": best["sequential"] / best["batched"],
        "quick": quick,
    }
    print(f"[serve] R={N_REQUESTS} cands>={out['n_candidates_min']} "
          f"seq={out['sequential_s']*1e3:.1f}ms "
          f"batched={out['batched_s']*1e3:.1f}ms "
          f"({out['speedup']:.1f}x, {out['req_per_s_batched']:.0f} req/s)",
          flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "serve_throughput.json"), "w") as f:
        json.dump(out, f, indent=1)
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: smaller G, fewer trials (same "
                         "64-request scale)")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="fail below this batched-vs-sequential ratio; use "
                         "a loose bound (e.g. 1.5) on noisy shared runners")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    if out["speedup"] < args.min_speedup:
        print(f"FAIL: micro-batched serving only {out['speedup']:.2f}x faster "
              f"(< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: micro-batched serving {out['speedup']:.1f}x faster than the "
          f"one-request-at-a-time loop (>= {args.min_speedup:g}x bar)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
