"""Paper Figs. 10-11: training losses of the GAN per w_critic.

Checks the paper's qualitative claims: with w_critic = 0 the critic loss
drifts up (D is ignored); with a proper w_critic all losses regress.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import train_gan_method, get_model, write_json


def run(models=("dnnweaver", "im2col"), w_critics=(0.0, 0.5, 1.0)) -> dict:
    out = {}
    for model_name in models:
        model = get_model(model_name)
        rows = []
        for w in w_critics:
            g, _ = train_gan_method(model, w)
            hist = g.state.history
            series = {
                k: [float(h[k]) for h in hist]
                for k in ("loss_g", "loss_d", "loss_config", "loss_critic",
                          "sat_rate")
            }
            n = len(series["loss_critic"])
            first = np.mean(series["loss_critic"][: max(n // 4, 1)])
            last = np.mean(series["loss_critic"][-max(n // 4, 1):])
            rows.append({"w_critic": w, "series": series,
                         "critic_first_quarter": float(first),
                         "critic_last_quarter": float(last)})
            print(f"[losses:{model_name}] w={w} critic {first:.3f}->{last:.3f} "
                  f"loss_d {series['loss_d'][0]:.3f}->{series['loss_d'][-1]:.3f}",
                  flush=True)
        out[model_name] = rows
    write_json("losses.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
