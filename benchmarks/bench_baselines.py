"""Baseline exploration throughput: batched device routes vs the legacy
sequential host loops.

PR1/PR2 made the GANDSE path device-resident; this bench gates the same
treatment for the baselines, at the same serving scale as
bench_explore_throughput (T=64 tasks on the high-dimension im2col space):

- **LargeMLP**: vmapped noise-averaged forward -> on-device candidate
  enumeration -> batched Algorithm 2, vs the per-task host loop
  (itertools.product + per-task select);
- **SimulatedAnnealing**: one jitted ``lax.while_loop`` anneal vmapped over
  tasks, vs the host loop's one ``evaluate_indices`` call per visited
  config;
- **PolicyGradientDRL**: the rollout as one jitted ``lax.scan`` vmapped
  over tasks, vs per-step host oracle calls + per-step policy dispatches.

  PYTHONPATH=src python benchmarks/bench_baselines.py [--quick]

Timings are interleaved min-of-trials after a warmup/compile pass.  The
acceptance bar: every baseline's batched route >= 5x its sequential loop
(use ``--min-speedup 2`` on noisy shared CI runners).  Exits nonzero below
the bar and appends each run to the repo-root ``BENCH_baselines.json``
trajectory (``results/bench_baselines.json`` holds the latest).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

from repro.baselines.drl import PolicyGradientDRL
from repro.baselines.mlp import LargeMLP
from repro.baselines.sa import SimulatedAnnealing
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
#: distinct env var from bench_explore_throughput's REPRO_BENCH_TRAJECTORY:
#: the two trajectories have different schemas and must never share a file
TRAJECTORY = os.environ.get("REPRO_BENCH_BASELINES_TRAJECTORY",
                            "BENCH_baselines.json")

N_TASKS = 64


def build(quick: bool):
    """Random-init nets at serving scale: exploration throughput depends on
    the dispatch structure, not on training quality (the same rule as
    bench_explore_throughput)."""
    model = Im2colModel()
    ds = generate_dataset(model, 512, seed=0)
    tasks = generate_tasks(model, N_TASKS, seed=2)

    layers, neurons = (1, 64) if quick else (2, 256)
    # threshold below uniform employs every choice; the trim caps the
    # product in (cap/2, cap] so every task carries > 1024 candidates
    mlp = LargeMLP(model, hidden_layers=layers, neurons=neurons,
                   explorer_cfg=ExplorerConfig(prob_threshold=0.01,
                                               max_candidates=2048))
    mlp.attach(ds, mlp.init_params(3))

    drl = PolicyGradientDRL(model, hidden_layers=layers, neurons=neurons)
    drl.attach(ds, drl.init_params(4))

    sa = SimulatedAnnealing(model)
    return {"mlp": mlp, "sa": sa, "drl": drl}, tasks


def run(quick: bool = False) -> Dict:
    methods, tasks = build(quick)

    # warmup: compile both routes per method
    for m in methods.values():
        m.explore_tasks(tasks, seed=0)
        m.explore_tasks(tasks, seed=0, batched=False)

    trials = 2 if quick else 3
    out: Dict = {"n_tasks": N_TASKS, "quick": quick, "methods": {}}
    for name, m in methods.items():
        best = {"batched": float("inf"), "sequential": float("inf")}
        for _ in range(trials):                # interleaved: noise-robust
            t0 = time.perf_counter()
            m.explore_tasks(tasks, seed=0)
            best["batched"] = min(best["batched"], time.perf_counter() - t0)
            t0 = time.perf_counter()
            m.explore_tasks(tasks, seed=0, batched=False)
            best["sequential"] = min(best["sequential"],
                                     time.perf_counter() - t0)
        row = {
            "sequential_s": best["sequential"],
            "batched_s": best["batched"],
            "tasks_per_s_batched": N_TASKS / best["batched"],
            "speedup": best["sequential"] / best["batched"],
        }
        out["methods"][name] = row
        print(f"[bench_baselines] {name:4s} T={N_TASKS} "
              f"seq={row['sequential_s']*1e3:.1f}ms "
              f"batched={row['batched_s']*1e3:.1f}ms "
              f"({row['speedup']:.1f}x, "
              f"{row['tasks_per_s_batched']:.0f} tasks/s)", flush=True)
    out["min_speedup"] = float(min(r["speedup"]
                                   for r in out["methods"].values()))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "bench_baselines.json"), "w") as f:
        json.dump(out, f, indent=1)
    traj = []
    if os.path.exists(TRAJECTORY):
        with open(TRAJECTORY) as f:
            traj = json.load(f)
    traj.append(out)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: smaller nets, fewer trials (same "
                         "64-task batch)")
    ap.add_argument("--min-speedup", type=float, default=5.0,
                    help="fail if any baseline's batched route is below "
                         "this ratio; loosen (e.g. 2.0) on noisy runners")
    args = ap.parse_args(argv)
    out = run(quick=args.quick)
    slowest = min(out["methods"], key=lambda k: out["methods"][k]["speedup"])
    if out["min_speedup"] < args.min_speedup:
        print(f"FAIL: {slowest} batched route only "
              f"{out['min_speedup']:.2f}x its sequential loop "
              f"(< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: every batched baseline >= {out['min_speedup']:.1f}x its "
          f"sequential loop (bar {args.min_speedup:g}x, slowest: {slowest})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
