"""Beyond-paper: GAN-DSE over the TPU-mesh design space vs exhaustive
search (the space is small enough to enumerate, giving exact regret)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_json
from repro.core.dse_api import GANDSE
from repro.core.gan import GANConfig
from repro.dataset.generator import generate_tasks
from repro.design_models.tpu_mesh import TpuMeshModel


def exhaustive_best(model, net_idx, lo, po):
    space = model.space
    # enumerate the whole mesh space (7840 configs)
    idx = np.indices([d.n for d in space.dims]).reshape(space.n_dims, -1).T
    net = np.repeat(net_idx[None], idx.shape[0], axis=0)
    lat, pw = model.evaluate_indices(net, idx)
    ok = (lat <= lo) & (pw <= po)
    if not ok.any():
        return None
    j = np.flatnonzero(ok)
    best = j[np.argmin(lat[j] / lo + pw[j] / po)]
    return float(lat[best]), float(pw[best])


def run(n_tasks=40) -> dict:
    model = TpuMeshModel()
    cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=1.0).scaled(
        layers=3, neurons=256, batch_size=512, lr=1e-4)
    g = GANDSE(model, cfg)
    t0 = time.time()
    g.train(n_data=8000, iters=8, seed=0)
    t_train = time.time() - t0

    tasks = generate_tasks(model, n_tasks, seed=2, slack=(1.1, 2.0))
    res = g.explore_tasks(tasks)
    sat, regret = 0, []
    possible = 0
    for i, r in enumerate(res):
        ex = exhaustive_best(model, tasks.net_idx[i], tasks.lat_obj[i],
                             tasks.pow_obj[i])
        if ex is None:
            continue
        possible += 1
        if r.satisfied:
            sat += 1
            regret.append(r.selection.latency / max(ex[0], 1e-12))
    out = {
        "train_time_s": t_train,
        "tasks_satisfiable": possible,
        "gan_satisfied": sat,
        "mean_latency_vs_exhaustive": float(np.mean(regret)) if regret else None,
        "dse_time_s": float(np.mean([r.dse_seconds for r in res])),
    }
    print(f"[mesh_dse] sat={sat}/{possible} "
          f"latency_vs_exhaustive={out['mean_latency_vs_exhaustive']} "
          f"dse={out['dse_time_s']*1e3:.0f}ms", flush=True)
    write_json("mesh_dse.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
