"""Shared benchmark harness: trains each DSE method once per design model
(reduced scale for CPU; paper scale documented in EXPERIMENTS.md) and
caches the trained explorers for the per-figure benchmarks."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.baselines.drl import PolicyGradientDRL
from repro.baselines.mlp import LargeMLP
from repro.baselines.sa import SimulatedAnnealing
from repro.core.dse_api import DSEResult, GANDSE, summarize
from repro.core.gan import GANConfig
from repro.dataset.generator import Dataset, DSETask, generate_dataset, generate_tasks
from repro.design_models.dnnweaver import DnnWeaverModel
from repro.design_models.im2col import Im2colModel

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")

# Reduced-scale training budget (CPU CI).  Paper scale: 11-14 layers x 2048
# neurons, ~1e5 s on an RTX 3090; see EXPERIMENTS.md §Scale.
SCALE = {
    "layers": int(os.environ.get("REPRO_GAN_LAYERS", 3)),
    "neurons": int(os.environ.get("REPRO_GAN_NEURONS", 256)),
    "iters": int(os.environ.get("REPRO_GAN_ITERS", 8)),
    "n_data": int(os.environ.get("REPRO_GAN_DATA", 8000)),
    "n_tasks": int(os.environ.get("REPRO_GAN_TASKS", 200)),
    "lr": float(os.environ.get("REPRO_GAN_LR", 1e-4)),
}


def get_model(name: str):
    return Im2colModel() if name == "im2col" else DnnWeaverModel()


@dataclasses.dataclass
class MethodResult:
    method: str
    w_critic: Optional[float]
    train_time_s: float
    results: List[DSEResult]

    def summary(self) -> Dict:
        s = summarize(self.results)
        s.update(method=self.method, w_critic=self.w_critic,
                 train_time_s=round(self.train_time_s, 1))
        return s


_CACHE: Dict = {}


def shared_dataset(model) -> Dataset:
    key = ("ds", model.name)
    if key not in _CACHE:
        _CACHE[key] = generate_dataset(model, SCALE["n_data"], seed=0)
    return _CACHE[key]


def shared_tasks(model, slack=(1.0, 2.5)) -> DSETask:
    key = ("tasks", model.name, slack)
    if key not in _CACHE:
        _CACHE[key] = generate_tasks(model, SCALE["n_tasks"], seed=1,
                                     slack=slack)
    return _CACHE[key]


def train_gan_method(model, w_critic: float, seed: int = 0) -> GANDSE:
    key = ("gan", model.name, w_critic, seed)
    if key not in _CACHE:
        cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=w_critic).scaled(
            layers=SCALE["layers"], neurons=SCALE["neurons"],
            lr=SCALE["lr"], batch_size=512)
        g = GANDSE(model, cfg)
        t0 = time.time()
        g.train(n_data=SCALE["n_data"], iters=SCALE["iters"], seed=seed,
                ds=shared_dataset(model))
        _CACHE[key] = (g, time.time() - t0)
    return _CACHE[key]


def train_mlp_method(model, seed: int = 0):
    key = ("mlp", model.name, seed)
    if key not in _CACHE:
        # parameter-matched to GAN G+D: ~2x layers at same width
        mlp = LargeMLP(model, hidden_layers=2 * SCALE["layers"],
                       neurons=SCALE["neurons"], lr=SCALE["lr"])
        t0 = time.time()
        mlp.train(n_data=SCALE["n_data"], iters=SCALE["iters"], seed=seed,
                  ds=shared_dataset(model))
        _CACHE[key] = (mlp, time.time() - t0)
    return _CACHE[key]


def train_drl_method(model, seed: int = 0):
    key = ("drl", model.name, seed)
    if key not in _CACHE:
        drl = PolicyGradientDRL(model)
        t0 = time.time()
        drl.train(n_data=SCALE["n_data"], iters=SCALE["iters"] * 4,
                  seed=seed, ds=shared_dataset(model))
        _CACHE[key] = (drl, time.time() - t0)
    return _CACHE[key]


def run_all_methods(model_name: str, w_critics=(0.0, 0.5, 1.0, 1.2)
                    ) -> List[MethodResult]:
    model = get_model(model_name)
    tasks = shared_tasks(model)
    out: List[MethodResult] = []

    sa = SimulatedAnnealing(model)
    t0 = time.time()
    out.append(MethodResult("SA", None, 0.0, sa.explore_tasks(tasks)))

    drl, t_drl = train_drl_method(model)
    out.append(MethodResult("DRL", None, t_drl, drl.explore_tasks(tasks)))

    mlp, t_mlp = train_mlp_method(model)
    out.append(MethodResult("LargeMLP", None, t_mlp, mlp.explore_tasks(tasks)))

    for w in w_critics:
        g, t_g = train_gan_method(model, w)
        out.append(MethodResult("GAN", w, t_g, g.explore_tasks(tasks)))
    return out


def write_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def append_trajectory(path: str, entry: dict) -> None:
    """Append one run to a repo-root BENCH_*.json perf trajectory (the
    cross-PR history the benches keep next to their latest results/)."""
    traj = []
    if os.path.exists(path):
        with open(path) as f:
            traj = json.load(f)
    traj.append(entry)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1)
