"""Paper Figs. 6-7: satisfied fraction vs objective difficulty.

Difficulty of (LO, PO) = normalized Euclidean distance to the nearest
Pareto frontier of the dataset (§7.4); the bench reports the satisfied
percentage among the topmost n% most difficult tasks.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (get_model, run_all_methods, shared_dataset,
                               shared_tasks, write_json)


def pareto_frontier(lat: np.ndarray, pw: np.ndarray) -> np.ndarray:
    """Indices of non-dominated points (min latency, min power)."""
    order = np.argsort(lat, kind="stable")
    best_p = np.inf
    keep = []
    for i in order:
        if pw[i] < best_p - 1e-15:
            keep.append(i)
            best_p = pw[i]
    return np.asarray(keep)


def task_difficulties(model, tasks) -> np.ndarray:
    ds = shared_dataset(model)
    pf = pareto_frontier(ds.latency, ds.power)
    pl, pp = ds.latency[pf], ds.power[pf]
    # normalize axes by dataset std (objectives live on very different scales)
    sl, sp = ds.latency.std() + 1e-12, ds.power.std() + 1e-12
    d = np.empty(len(tasks.lat_obj))
    for i, (lo, po) in enumerate(zip(tasks.lat_obj, tasks.pow_obj)):
        dist = np.sqrt(((pl - lo) / sl) ** 2 + ((pp - po) / sp) ** 2)
        j = int(np.argmin(dist))
        mod = np.sqrt((pl[j] / sl) ** 2 + (pp[j] / sp) ** 2) + 1e-12
        d[i] = dist[j] / mod
    return d


def run(models=("dnnweaver", "im2col"),
        percents=(10, 25, 50, 75, 100)) -> dict:
    out = {}
    for model_name in models:
        model = get_model(model_name)
        tasks = shared_tasks(model)
        diff = task_difficulties(model, tasks)
        hard_order = np.argsort(-diff)        # most difficult first
        rows = []
        for mr in run_all_methods(model_name):
            sat = np.array([r.satisfied for r in mr.results])
            curve = {}
            for pct in percents:
                k = max(int(len(sat) * pct / 100), 1)
                curve[pct] = float(sat[hard_order[:k]].mean())
            tag = mr.method + (f"(w={mr.w_critic})" if mr.w_critic is not None else "")
            rows.append({"method": tag, "curve": curve})
            print(f"[difficulty:{model_name}] {tag:14s} "
                  + " ".join(f"top{p}%={curve[p]:.2f}" for p in percents),
                  flush=True)
        out[model_name] = rows
    write_json("difficulty.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
