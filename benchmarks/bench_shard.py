"""Task-throughput scaling of the sharded exploration path (1 -> N devices).

The batched DSE routes vmap independent task lanes, so sharding the task
axis over the device mesh (`repro.core.shard`) should scale throughput
near-linearly with device count.  This bench measures `GANDSE
.explore_batch` on the high-dimension im2col space (64 tasks x >= 1024
candidates each, the bench_explore_throughput scale) under submeshes of
1..N devices built by ``make_host_mesh(shape=(k, 1))``, and pins the
parity contract: every device count returns bit-identical Selections.

  PYTHONPATH=src python benchmarks/bench_shard.py [--quick] [--devices N]

Device count defaults to 4 fake CPU devices (``REPRO_SHARD_DEVICES``
overrides): the flag is injected into ``XLA_FLAGS`` before jax imports,
so run this script as __main__ (importing it after jax is initialized
keeps whatever device count the process already has).

Acceptance bar (bench_fused_train precedent): fake CPU devices only
parallelize when the host has cores to back them, so the >= 3x @ 4
devices throughput gate arms only when ``os.cpu_count() >= devices`` or
the backend is a real multi-device one (TPU/GPU); on smaller hosts the
bench *gates parity* and reports the measured scaling honestly.  Each run
appends to the repo-root ``BENCH_shard.json`` trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

N_DEVICES = int(os.environ.get("REPRO_SHARD_DEVICES", 4))
if __name__ == "__main__" and "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={N_DEVICES}"
        ).strip()

import time
from typing import Dict

import jax
import numpy as np

from repro.core import gan as G
from repro.core import shard
from repro.core.dse_api import GANDSE
from repro.core.explorer import ExplorerConfig
from repro.dataset.generator import generate_dataset, generate_tasks
from repro.design_models.im2col import Im2colModel
from repro.launch.mesh import make_host_mesh

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
TRAJECTORY = os.environ.get("REPRO_BENCH_TRAJECTORY", "BENCH_shard.json")


def build(quick: bool):
    """Random-init G at serving scale (scaling does not depend on training
    quality, only on the dispatch structure) — bench_explore_throughput's
    build, shared scale."""
    model = Im2colModel()
    layers, neurons = (1, 64) if quick else (2, 256)
    cfg = G.GANConfig(n_net=model.net_space.n_dims).scaled(
        layers=layers, neurons=neurons, batch_size=64)
    g = GANDSE(model, cfg, ExplorerConfig(prob_threshold=0.01,
                                          max_candidates=2048))
    ds = generate_dataset(model, 512, seed=0)
    g.attach(ds, G.init_generator(jax.random.PRNGKey(3), cfg, model.space))
    tasks = generate_tasks(model, 64, seed=2)
    return g, tasks


def _selections(results):
    return [(tuple(r.selection.cfg_idx.tolist())
             if r.selection.cfg_idx is not None else None,
             r.selection.latency, r.selection.power, r.selection.satisfied)
            for r in results]


def run(quick: bool = False, devices: int = 0) -> Dict:
    n_dev = devices or len(jax.devices())
    n_dev = min(n_dev, len(jax.devices()))
    g, tasks = build(quick)
    n_tasks = int(tasks.net_idx.shape[0])
    # 1 and n_dev always; intermediate pow2 points on the full run
    ks = sorted({1, n_dev} | ({2} if not quick and n_dev >= 4 else set()))
    meshes = {k: make_host_mesh(shape=(k, 1)) for k in ks}

    # warmup / compile each submesh route, and pin parity against k=1
    baseline = None
    for k in ks:
        with shard.task_mesh(meshes[k]):
            sel = _selections(g.explore_batch(tasks, seed=0))
        if baseline is None:
            baseline = sel
        assert sel == baseline, \
            f"parity violated: k={k} Selections differ from k=1"

    trials = 2 if quick else 3
    best = {k: float("inf") for k in ks}
    for _ in range(trials):                    # interleaved: noise-robust
        for k in ks:
            with shard.task_mesh(meshes[k]):
                t0 = time.perf_counter()
                g.explore_batch(tasks, seed=0)
                best[k] = min(best[k], time.perf_counter() - t0)

    cores = os.cpu_count() or 1
    real_multidevice = jax.default_backend() in ("tpu", "gpu") \
        and len(jax.devices()) > 1
    out = {
        "n_tasks": n_tasks,
        "backend": jax.default_backend(),
        "host_cores": cores,
        "devices": n_dev,
        "seconds": {str(k): best[k] for k in ks},
        "tasks_per_s": {str(k): n_tasks / best[k] for k in ks},
        "scaling": best[1] / best[n_dev],
        "parity_ok": True,
        # fake CPU devices cannot beat wall-clock without cores behind them
        "speedup_gate_armed": real_multidevice or cores >= n_dev,
        "quick": quick,
    }
    per_k = " ".join(f"k={k}:{best[k]*1e3:.0f}ms" for k in ks)
    print(f"[shard] T={n_tasks} devices={n_dev} cores={cores} {per_k} "
          f"scaling={out['scaling']:.2f}x parity=ok "
          f"gate={'armed' if out['speedup_gate_armed'] else 'parity-only'}",
          flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "shard_scaling.json"), "w") as f:
        json.dump(out, f, indent=1)
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import append_trajectory
    append_trajectory(TRAJECTORY, out)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: smaller G, fewer trials, "
                         "endpoints only")
    ap.add_argument("--devices", type=int, default=0,
                    help="device-count ceiling (0 = all visible devices)")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="fail below this 1->N throughput ratio when the "
                         "speedup gate is armed (host cores >= devices or "
                         "a real multi-device backend)")
    args = ap.parse_args(argv)
    out = run(quick=args.quick, devices=args.devices)
    if not out["speedup_gate_armed"]:
        print(f"ok: parity pinned at every device count; speedup gate "
              f"skipped ({out['host_cores']} host cores < "
              f"{out['devices']} devices — fake devices share them)")
        return 0
    if out["scaling"] < args.min_speedup:
        print(f"FAIL: {out['devices']}-device scaling only "
              f"{out['scaling']:.2f}x (< {args.min_speedup:g}x bar)")
        return 1
    print(f"ok: {out['scaling']:.2f}x task throughput at {out['devices']} "
          f"devices (>= {args.min_speedup:g}x bar), parity pinned")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
