"""Paper Table 5 + Fig. 5: methods x design models — satisfied counts,
improvement ratio, DSE time, candidate counts, error stds."""
from __future__ import annotations

import time

from benchmarks.common import run_all_methods, write_json


def run(models=("dnnweaver", "im2col")) -> dict:
    table = {}
    for model_name in models:
        rows = []
        for mr in run_all_methods(model_name):
            s = mr.summary()
            rows.append(s)
            tag = (f"{s['method']}" + (f"(w={s['w_critic']})"
                                       if s["w_critic"] is not None else ""))
            print(f"[table5:{model_name}] {tag:14s} "
                  f"sat={s['n_satisfied']}/{s['n_tasks']} "
                  f"impr={s['improvement_ratio']:.4f} "
                  f"dse={s['dse_time_s']*1e3:.1f}ms "
                  f"cand={s['n_candidates']:.1f} "
                  f"std(L)={s['lat_err_std']:.3f} std(P)={s['pow_err_std']:.3f}",
                  flush=True)
        table[model_name] = rows
    write_json("table5.json", table)
    return table


def main():
    run()


if __name__ == "__main__":
    main()
