"""§Perf hillclimb C (paper-representative): GAN-DSE proposes the
parallelism config for qwen3-14b:train_4k, and each proposal is VALIDATED
by actually lowering + compiling the cell on the proposed elastic mesh —
closing the loop between the paper's technique and this framework's
runtime.

  PYTHONPATH=src python -m benchmarks.bench_gan_hillclimb
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import time

import numpy as np

from benchmarks.common import write_json
from repro import configs
from repro.configs.shapes import SHAPES
from repro.core.dse_api import GANDSE
from repro.core.gan import GANConfig
from repro.design_models.tpu_mesh import TpuMeshModel


def gan_proposals(n_best: int = 3, step_obj: float = 0.6,
                  power_obj: float = 80e3, seeds=(0, 1, 2, 3)):
    """Train the mesh-DSE GAN and collect distinct single-pod 256-chip
    proposals (PODS=1, DP*TP=256) for the qwen3-14b train_4k workload."""
    model = TpuMeshModel()
    cfg = GANConfig(n_net=model.net_space.n_dims, w_critic=1.0).scaled(
        layers=3, neurons=256, batch_size=512, lr=1e-4)
    g = GANDSE(model, cfg)
    g.train(n_data=8000, iters=8, seed=0)

    # qwen3-14b train_4k: 40L x 5120, dff ~3.4x, seq 4096, batch 256
    net = model.net_space.indices_from_values(
        np.array([[40., 5120., 3., 4096., 256., 131072.]]))[0]
    # collect the GAN's candidate sets across noise seeds, keep only
    # single-pod 256-chip configs (our dry-run budget), rank by the
    # design model's latency
    from repro.core.explorer import enumerate_candidates
    cands = []
    for s in seeds:
        probs = g._explorer.generator_probs(net, step_obj, power_obj, seed=s)[0]
        cands.append(enumerate_candidates(model.space, probs, 0.1, 4096))
    cand = np.unique(np.concatenate(cands), axis=0)
    vals = model.space.values_from_indices(cand)
    keep = (vals[:, 0] == 1) & (vals[:, 1] * vals[:, 2] == 256)
    cand, vals = cand[keep], vals[keep]
    if cand.size == 0:
        return []
    lat, pw = model.evaluate_indices(
        np.repeat(net[None], cand.shape[0], 0), cand)
    order = np.argsort(np.where(np.isfinite(lat), lat, np.inf))
    out, seen = [], set()
    for j in order:
        c = {d.name: v for d, v in zip(model.space.dims, vals[j])}
        key = (c["DP"], c["TP"], c["MICRO"], c["REMAT"])
        if key in seen or not np.isfinite(lat[j]):
            continue
        seen.add(key)
        out.append({"config": c, "predicted_step_s": float(lat[j]),
                    "predicted_power_w": float(pw[j])})
        if len(out) >= n_best:
            break
    return out


def validate(config: dict) -> dict:
    """Lower + compile qwen3 train_4k on the proposed mesh; roofline it."""
    import jax
    from repro.launch.dryrun import model_flops_for
    from repro.launch.mesh import make_mesh
    from repro.train import step as TS
    from repro.utils import roofline as RL

    dp, tp = int(config["DP"]), int(config["TP"])
    micro = int(config["MICRO"])
    remat = bool(config["REMAT"])
    mesh = make_mesh((dp, tp), ("data", "model"))
    m = configs.get_arch("qwen3-14b")
    shape = SHAPES["train_4k"]
    t0 = time.time()
    try:
        case = TS.build_case(m, shape, mesh, microbatches=micro, remat=remat)
        with mesh:
            compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                               donate_argnums=case.donate_argnums
                               ).lower(*case.args).compile()
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        rl = RL.from_compiled(case.name, compiled, hlo, dp * tp,
                              model_flops=model_flops_for(m, shape,
                                                          case.args[0]))
        return {
            "status": "ok", "mesh": f"{dp}x{tp}", "micro": micro,
            "remat": remat,
            "t_bound": rl.t_bound, "bottleneck": rl.bottleneck,
            "t_compute_s": rl.t_compute, "t_memory_s": rl.t_memory,
            "t_collective_s": rl.t_collective,
            "mfu_bound": rl.mfu_bound,
            "bytes_per_device": int(mem.temp_size_in_bytes
                                    + mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    - mem.alias_size_in_bytes),
            "compile_s": round(time.time() - t0, 1),
        }
    except Exception as e:
        return {"status": "fail", "mesh": f"{dp}x{tp}", "micro": micro,
                "error": f"{type(e).__name__}: {str(e)[:200]}"}


def run() -> dict:
    # baseline = the default dry-run config (16x16, micro=2, remat)
    baseline = validate({"DP": 16, "TP": 16, "MICRO": 2, "REMAT": 1})
    print(f"[gan_hillclimb] baseline 16x16: t_bound={baseline.get('t_bound', 0):.3f}s "
          f"({baseline.get('bottleneck')}) mfu<={baseline.get('mfu_bound', 0):.3f}",
          flush=True)
    props = gan_proposals()
    rows = []
    for p in props:
        v = validate(p["config"])
        rows.append({**p, "validated": v})
        if v["status"] == "ok":
            print(f"[gan_hillclimb] GAN {v['mesh']} micro={v['micro']} "
                  f"remat={v['remat']}: t_bound={v['t_bound']:.3f}s "
                  f"({v['bottleneck']}) mfu<={v['mfu_bound']:.3f} "
                  f"mem={v['bytes_per_device']/1e9:.1f}GB", flush=True)
        else:
            print(f"[gan_hillclimb] GAN {v['mesh']}: FAIL {v['error']}",
                  flush=True)
    out = {"baseline": baseline, "proposals": rows}
    write_json("gan_hillclimb.json", out)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
